"""End-to-end training driver (deliverable b): proxy-fed pipeline, async
proxy checkpoints, crash-resume, any assigned --arch.

Thin wrapper over ``repro.launch.train``; see that module for flags.

    PYTHONPATH=src python examples/train_e2e.py --arch phi4-mini-3.8b \
        --preset small --steps 200
    # kill it mid-run, then add --resume: it continues from the last
    # proxy-checkpoint manifest with an identical data stream.
"""
from repro.launch.train import main

if __name__ == "__main__":
    main()
