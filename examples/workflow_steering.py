"""Workflow steering (Colmena analog, paper §5.2/§5.6).

A thinker keeps simulation tasks in flight through a task server; results
above a threshold travel by proxy, keeping the server queue light.  Prints
the with/without-proxy comparison (Fig 7's quantity).

Run:  PYTHONPATH=src python examples/workflow_steering.py
"""
import os
import tempfile

import numpy as np

from repro.core import Store
from repro.core.connectors import SharedMemoryConnector
from repro.federated.steer import SteerConfig, Steering


def simulate(x: np.ndarray) -> np.ndarray:
    """A mock 'quantum chemistry' task: some FLOPs over the input."""
    return np.tanh(x @ x.T)


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="psj-steer-")
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((512, 512)).astype(np.float32)
              for _ in range(4)]  # ~1 MB each

    def make_input(i: int) -> np.ndarray:
        return inputs[i % len(inputs)]

    store = Store("steer-example",
                  SharedMemoryConnector(os.path.join(tmp, "shm")))
    with_proxy = Steering(SteerConfig(proxy_threshold=100_000), store)
    r1 = with_proxy.run(simulate, make_input, n_tasks=12)
    with_proxy.close()

    no_proxy = Steering(SteerConfig(proxy_threshold=None), None)
    r2 = no_proxy.run(simulate, make_input, n_tasks=12)
    no_proxy.close()

    speedup = (r2["wall_s"] - r1["wall_s"]) / r2["wall_s"] * 100
    print(f"with proxies:    {r1['wall_s']:.2f}s  "
          f"server moved {r1['server_bytes']:,} bytes")
    print(f"without proxies: {r2['wall_s']:.2f}s  "
          f"server moved {r2['server_bytes']:,} bytes")
    print(f"round-trip improvement: {speedup:.1f}%  "
          f"(server traffic reduced "
          f"{r2['server_bytes'] / max(r1['server_bytes'], 1):,.0f}x)")


if __name__ == "__main__":
    main()
