"""Quickstart: the paper's Listing 1, in this framework.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import pickle
import tempfile

import numpy as np

from repro.core import (MultiConnector, Policy, Store, borrow, clone,
                        get_factory, is_resolved, release, resolve_async)
from repro.core.connectors import (FileConnector, LocalMemoryConnector,
                                   SharedMemoryConnector)


def my_function(x):
    # consumer code is unaware it received a proxy: isinstance holds,
    # numpy operations forward transparently
    assert isinstance(x, dict)
    return float(np.sum(x["data"]))


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="psj-quickstart-")

    # -- Listing 1: store + proxy --------------------------------------
    store = Store("my-store", FileConnector(os.path.join(tmp, "store")))
    payload = {"data": np.arange(1_000_000, dtype=np.float32)}
    p = store.proxy(payload)

    wire = pickle.dumps(p)  # what a FaaS/workflow system would ship
    print(f"proxy pickles to {len(wire)} bytes "
          f"(data is {payload['data'].nbytes:,} bytes)")

    p2 = pickle.loads(wire)
    print("resolved before use?", is_resolved(p2))
    print("my_function(proxy) =", my_function(p2))   # just-in-time resolve
    print("resolved after use?", is_resolved(p2))

    # -- async resolution overlaps communication with compute ----------
    p3 = pickle.loads(pickle.dumps(store.proxy(payload)))
    resolve_async(p3)          # starts fetching in the background
    _ = sum(range(10_000))     # ... compute happens here ...
    print("async-resolved sum:", my_function(p3))

    # -- refcounted ephemeral intermediates -----------------------------
    # each sibling (including pickled copies) holds one reference; the key
    # is evicted after the LAST consumer resolves — never out from under
    # a sibling that has not resolved yet
    p4 = store.proxy(payload, evict=True)
    p5 = pickle.loads(pickle.dumps(p4))          # a second consumer
    key = get_factory(p4).key
    _ = my_function(p4)
    print("still alive for the sibling?", store.exists(key))
    _ = my_function(p5)
    print("evicted after the last resolve?", not store.exists(key))

    # -- explicit ownership: OwnedProxy + borrow/clone ------------------
    owned = store.owned_proxy(payload, ttl=60)   # lease bounds crash leaks
    b = borrow(owned)                            # non-owning view
    print("borrowed sum:", my_function(b))
    del b
    with clone(owned) as co_owner:               # a second owner
        _ = my_function(co_owner)
    release(owned)                               # last owner gone -> evicted
    print("owned key evicted?",
          not store.exists(get_factory(owned).key))

    # -- MultiConnector policy routing ----------------------------------
    multi = MultiConnector([
        (LocalMemoryConnector(), Policy(max_size=64 << 10, priority=10,
                                        tags=frozenset({"local"}))),
        (SharedMemoryConnector(os.path.join(tmp, "shm")),
         Policy(priority=5, tags=frozenset({"local", "node"}))),
        (FileConnector(os.path.join(tmp, "bulk")),
         Policy(priority=0, tags=frozenset({"local", "node", "persistent"}))),
    ])
    mstore = Store("multi-store", multi)
    small = mstore.put(b"tiny control message")
    rng = np.random.default_rng(0)
    big = mstore.put(rng.standard_normal(1_000_000).astype(np.float32))
    durable = mstore.put({"model": "weights"}, constraints=["persistent"])
    print("routing: small->", small[1], " big->", big[1],
          " persistent->", durable[1],
          " (0=memory, 1=shm, 2=file)")


if __name__ == "__main__":
    main()
