"""Federated learning across payload-capped FaaS workers (paper §5.5).

Shows the Fig 10 effect end-to-end: with ``--transport value`` the model
rides the (5 MB-capped, cloud-latency) control plane and large models fail;
with ``--transport proxy`` only ~300-byte references do.

Run:  PYTHONPATH=src python examples/federated_learning.py \
          [--rounds 3] [--transport proxy|value] [--compression int8]
"""
import argparse
import os
import tempfile

from repro.configs import ARCHS
from repro.core import Store
from repro.core.connectors import FileConnector
from repro.federated.faas import CloudModel, FaasExecutor
from repro.federated.fl import FLConfig, FLOrchestrator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--transport", default="proxy", choices=["proxy", "value"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "int8_ef", "topk"])
    ap.add_argument("--elastic", action="store_true",
                    help="vary worker count per round")
    args = ap.parse_args()

    cfg = ARCHS["phi4-mini-3.8b"].reduced().replace(
        n_layers=2, d_model=64, d_ff=128, vocab=256, dtype="float32")
    tmp = tempfile.mkdtemp(prefix="psj-fl-")
    executor = FaasExecutor(n_workers=args.workers,
                            cloud=CloudModel(latency_s=0.01))
    store = Store("fl-example", FileConnector(os.path.join(tmp, "store"))) \
        if args.transport == "proxy" else None

    fl = FLConfig(rounds=args.rounds, workers_per_round=args.workers,
                  local_steps=args.local_steps, transport=args.transport,
                  compression=args.compression)
    orch = FLOrchestrator(cfg, fl, executor, store)
    schedule = None
    if args.elastic:
        schedule = [max(1, args.workers + (-1) ** r * (r % 2))
                    for r in range(args.rounds)]
    result = orch.run(worker_schedule=schedule)
    print("global eval loss per round:",
          " -> ".join(f"{l:.4f}" for l in result["losses"]))
    for r in result["rounds"]:
        print(f"  round {r['round']}: workers={r['workers']} ok={r['ok']} "
              f"failures={r['failures']} stragglers={r['stragglers']} "
              f"({r['wall_s']:.2f}s)")
    executor.shutdown()


if __name__ == "__main__":
    main()
