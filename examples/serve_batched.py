"""Batched serving driver (deliverable b): prefill + KV-cache decode, weights
lazily restorable from a proxy-checkpoint manifest.

Thin wrapper over ``repro.launch.serve``; see that module for flags.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2.5-14b \
        --preset tiny --requests 4 --new-tokens 16
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
