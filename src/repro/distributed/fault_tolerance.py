"""Fault-tolerance primitives shared by the trainer and FL orchestrator.

* file-based heartbeats (worker liveness without a network dependency),
* retry-with-backoff wrapper,
* round deadlines with straggler over-provisioning math.
"""
from __future__ import annotations

import json
import random
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable


class HeartbeatWriter:
    def __init__(self, directory: str, worker_id: str) -> None:
        self.path = Path(directory) / f"{worker_id}.hb"
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, **info) -> None:
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"ts": time.time(), **info}))
        tmp.replace(self.path)


class HeartbeatMonitor:
    def __init__(self, directory: str, stale_s: float = 10.0) -> None:
        self.dir = Path(directory)
        self.stale_s = stale_s

    def alive(self) -> dict[str, dict]:
        out = {}
        now = time.time()
        for f in self.dir.glob("*.hb"):
            try:
                info = json.loads(f.read_text())
            except (json.JSONDecodeError, FileNotFoundError):
                continue
            if now - info.get("ts", 0) <= self.stale_s:
                out[f.stem] = info
        return out

    def dead(self, known: list[str]) -> list[str]:
        alive = self.alive()
        return [w for w in known if w not in alive]


@dataclass
class RetryPolicy:
    max_attempts: int = 3
    base_delay_s: float = 0.2
    max_delay_s: float = 5.0
    retry_on: tuple = (ConnectionError, TimeoutError, OSError)


def with_retries(fn: Callable[..., Any], policy: RetryPolicy = RetryPolicy()):
    def wrapped(*args, **kwargs):
        delay = policy.base_delay_s
        for attempt in range(policy.max_attempts):
            try:
                return fn(*args, **kwargs)
            except policy.retry_on:
                if attempt == policy.max_attempts - 1:
                    raise
                time.sleep(delay * (1 + 0.2 * random.random()))
                delay = min(delay * 2, policy.max_delay_s)
    return wrapped


def overprovision(n_required: int, p_failure: float,
                  confidence: float = 0.99) -> int:
    """Workers to launch so >= n_required finish with given confidence.

    Simple binomial-tail search (the straggler math behind FL round
    deadlines and redundant data producers).
    """
    import math

    n = n_required
    while n < 10 * n_required + 10:
        # P[successes >= n_required] with n trials
        p_ok = sum(
            math.comb(n, k) * (1 - p_failure) ** k * p_failure ** (n - k)
            for k in range(n_required, n + 1))
        if p_ok >= confidence:
            return n
        n += 1
    return n
