"""Fault-tolerance primitives shared by the trainer and FL orchestrator.

* file-based heartbeats (worker liveness without a network dependency),
* retry-with-backoff wrapper,
* round deadlines with straggler over-provisioning math.
"""
from __future__ import annotations

import json
import random
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable


class HeartbeatWriter:
    """Atomic file heartbeat.  Each beat carries a monotonically increasing
    ``seq`` so a reader can detect *change* without trusting wall-clock
    stamps across processes."""

    def __init__(self, directory: str, worker_id: str) -> None:
        self.path = Path(directory) / f"{worker_id}.hb"
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._seq = 0

    def beat(self, **info) -> None:
        self._seq += 1
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({  # lint: wallclock-ok (beat timestamp)
            "ts": time.time(), "seq": self._seq, **info}))
        tmp.replace(self.path)


class HeartbeatMonitor:
    """Staleness is judged on the *reader's monotonic clock*: a worker ages
    by the monotonic time since this monitor last observed its heartbeat
    change (seq / ts / mtime marker), not by comparing the writer's
    ``time.time()`` stamp with ours — the same NTP-step bug that once
    broke leases (PR 4) would otherwise mass-declare workers dead the
    instant a clock steps forward.  The only wall-clock read is the
    first-sight bootstrap (file-mtime delta, one same-host comparison),
    so a pre-existing stale file is still recognized as stale.
    """

    def __init__(self, directory: str, stale_s: float = 10.0) -> None:
        self.dir = Path(directory)
        self.stale_s = stale_s
        # worker -> (last marker, monotonic instant it last changed)
        self._seen: dict[str, tuple[tuple, float]] = {}

    def alive(self) -> dict[str, dict]:
        out = {}
        mono = time.monotonic()
        for f in self.dir.glob("*.hb"):
            try:
                info = json.loads(f.read_text())
                mtime = f.stat().st_mtime
            except (json.JSONDecodeError, FileNotFoundError, OSError):
                continue
            marker = (info.get("seq"), info.get("ts"), mtime)
            prev = self._seen.get(f.stem)
            if prev is None:            # first sight: mtime-delta bootstrap
                # wall-clock vs file mtime, by design  # lint: wallclock-ok
                age = max(0.0, time.time() - mtime)
                self._seen[f.stem] = (marker, mono - age)
            elif marker != prev[0]:     # beat observed: reset the age
                age = 0.0
                self._seen[f.stem] = (marker, mono)
            else:                       # unchanged: monotonic age
                age = mono - prev[1]
            if age <= self.stale_s:
                out[f.stem] = info
        return out

    def dead(self, known: list[str]) -> list[str]:
        alive = self.alive()
        return [w for w in known if w not in alive]


@dataclass
class RetryPolicy:
    """Jittered exponential backoff, capped per-delay AND in total.

    ``jitter`` spreads synchronized retry storms: each delay is scaled by
    a uniform factor in ``[1, 1 + jitter]``.  ``deadline_s`` (None = no
    cap) bounds the *total* time a retry loop may burn, measured on the
    monotonic clock from its first attempt — a caller on the failover
    path gives up and reroutes instead of backing off forever.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.2
    max_delay_s: float = 5.0
    retry_on: tuple = (ConnectionError, TimeoutError, OSError)
    jitter: float = 0.2
    deadline_s: float | None = None

    def delay_for(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-based): exponential from
        ``base_delay_s``, capped at ``max_delay_s``, jittered."""
        delay = min(self.base_delay_s * (2.0 ** max(0, attempt)),
                    self.max_delay_s)
        return delay * (1.0 + self.jitter * random.random())

    def expired(self, start_monotonic: float,
                next_delay: float = 0.0) -> bool:
        """True when sleeping ``next_delay`` more seconds would overrun
        the total deadline (sleeping past it just delays the inevitable
        failure — fail now and let the caller reroute)."""
        if self.deadline_s is None:
            return False
        elapsed = time.monotonic() - start_monotonic
        return elapsed + next_delay >= self.deadline_s


def with_retries(fn: Callable[..., Any], policy: RetryPolicy = RetryPolicy()):
    """Wrap ``fn`` to retry on ``policy.retry_on`` with the policy's
    jittered exponential backoff, bounded by ``max_attempts`` and (when
    set) the total ``deadline_s`` budget."""
    def wrapped(*args, **kwargs):
        start = time.monotonic()
        for attempt in range(policy.max_attempts):
            try:
                return fn(*args, **kwargs)
            except policy.retry_on:
                if attempt == policy.max_attempts - 1:
                    raise
                delay = policy.delay_for(attempt)
                if policy.expired(start, delay):
                    raise
                time.sleep(delay)
    return wrapped


def overprovision(n_required: int, p_failure: float,
                  confidence: float = 0.99) -> int:
    """Workers to launch so >= n_required finish with given confidence.

    Simple binomial-tail search (the straggler math behind FL round
    deadlines and redundant data producers).
    """
    import math

    n = n_required
    while n < 10 * n_required + 10:
        # P[successes >= n_required] with n trials
        p_ok = sum(
            math.comb(n, k) * (1 - p_failure) ** k * p_failure ** (n - k)
            for k in range(n_required, n + 1))
        if p_ok >= confidence:
            return n
        n += 1
    return n
