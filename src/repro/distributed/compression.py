"""Gradient/update compression for the federated update path (+ tests).

* ``int8``: per-tensor symmetric quantization, 4x smaller than fp32.
* ``int8_ef``: int8 with error feedback — the residual of each round is
  added back before the next quantization, making compression *unbiased
  over time* (Seide et al.; standard in comm-efficient FL).
* ``topk``: magnitude sparsification (indices + values), with EF.

All operate on pytrees of numpy/jax arrays and return plain-dict payloads
that serialize compactly through the Store.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np


def _quant_int8(x: np.ndarray) -> dict:
    scale = float(np.max(np.abs(x)) or 1.0) / 127.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return {"q": q, "scale": scale, "kind": "int8"}


def _dequant_int8(p: dict) -> np.ndarray:
    return p["q"].astype(np.float32) * p["scale"]


def _topk(x: np.ndarray, frac: float) -> dict:
    flat = x.reshape(-1)
    k = max(1, int(len(flat) * frac))
    idx = np.argpartition(np.abs(flat), -k)[-k:].astype(np.int32)
    return {"idx": idx, "val": flat[idx].astype(np.float32),
            "shape": list(x.shape), "kind": "topk"}


def _untopk(p: dict) -> np.ndarray:
    flat = np.zeros(int(np.prod(p["shape"])), np.float32)
    flat[p["idx"]] = p["val"]
    return flat.reshape(p["shape"])


class Compressor:
    """Stateful (error-feedback) tree compressor."""

    def __init__(self, method: str = "int8_ef", topk_frac: float = 0.05):
        assert method in ("none", "int8", "int8_ef", "topk", "topk_ef")
        self.method = method
        self.topk_frac = topk_frac
        self._residual: Any = None

    def compress(self, tree) -> Any:
        if self.method == "none":
            return jax.tree.map(np.asarray, tree)
        use_ef = self.method.endswith("_ef")
        base = self.method.replace("_ef", "")
        leaves, treedef = jax.tree_util.tree_flatten(
            jax.tree.map(lambda a: np.asarray(a, np.float32), tree))
        if use_ef and self._residual is None:
            self._residual = [np.zeros_like(l) for l in leaves]
        out, new_res = [], []
        for i, leaf in enumerate(leaves):
            if use_ef:
                leaf = leaf + self._residual[i]
            payload = _quant_int8(leaf) if base == "int8" \
                else _topk(leaf, self.topk_frac)
            if use_ef:
                approx = _dequant_int8(payload) if base == "int8" \
                    else _untopk(payload)
                new_res.append(leaf - approx)
            out.append(payload)
        if use_ef:
            self._residual = new_res
        return {"treedef": treedef, "leaves": out, "kind": "compressed"}

    @staticmethod
    def decompress(payload) -> Any:
        if not (isinstance(payload, dict) and
                payload.get("kind") == "compressed"):
            return payload
        leaves = [
            _dequant_int8(p) if p["kind"] == "int8" else _untopk(p)
            for p in payload["leaves"]
        ]
        return jax.tree_util.tree_unflatten(payload["treedef"], leaves)

    @staticmethod
    def payload_bytes(payload) -> int:
        from repro.core import frame_nbytes, serialize

        return frame_nbytes(serialize(payload))
