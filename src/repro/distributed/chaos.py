"""Fault-injection harness for the KV fabric (and anything TCP).

Two layers:

* :class:`ChaosProxy` — a thread-per-connection TCP shim that sits between
  a client and one upstream server and injects faults **on command**:

  - ``kill()``          — stop listening and sever every connection (the
    process-death look-alike for servers you can't SIGKILL, e.g. in-proc);
  - ``blackhole(True)`` — silently drop all forwarded bytes (both
    directions) while leaving connections open: the client sees pure
    timeout, not a reset;
  - ``set_delay(s)``    — sleep ``s`` before forwarding each chunk (ack
    delay / slow-network emulation);
  - ``corrupt_next()``  — XOR the first 4 bytes of the next client→server
    chunk.  Those bytes are a frame-length header, so the server sees a
    length ≥ 2 GiB and must declare the stream dead — the corruption-
    detection path the fabric tests assert on;
  - ``reset_conns()``   — drop live connections but keep listening, so the
    next request exercises the client's transparent-reconnect + retry path
    deterministically.

* :func:`kill_shard` — SIGKILL a spawned server's whole process group: the
  real thing, used by the failover tests and the fig15 recovery benchmark.

The proxy listens on loopback TCP and forwards to either a TCP or a
``unix:/path`` upstream, so it can front fabric shards regardless of
transport.  All faults are plain attribute flips — safe to toggle from the
test thread while pumps are mid-transfer.
"""
from __future__ import annotations

import os
import signal
import socket
import threading
import time

from repro.core.kv_tcp import is_uds, uds_path

_CHUNK = 1 << 16


class ChaosProxy:
    """TCP shim with switchable fault injection (see module docstring).

    Usage::

        proxy = ChaosProxy(shard.host, shard.port)
        client = KVClient("127.0.0.1", proxy.port)
        proxy.corrupt_next()
        ...
        proxy.close()
    """

    def __init__(self, upstream_host: str, upstream_port: int = 0,
                 listen_host: str = "127.0.0.1") -> None:
        self.upstream_host = upstream_host
        self.upstream_port = upstream_port
        self._delay = 0.0
        self._blackhole = False
        self._corrupt_c2s = 0            # countdown of chunks to corrupt
        self._killed = False
        self._lock = threading.Lock()
        self._conns: list[tuple[socket.socket, socket.socket]] = []
        self._listener = socket.socket()
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((listen_host, 0))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"chaos-accept-{self.port}",
            daemon=True)
        self._accept_thread.start()

    # -- fault switches ------------------------------------------------------
    def set_delay(self, seconds: float) -> None:
        """Sleep ``seconds`` before forwarding each chunk (both ways)."""
        self._delay = max(0.0, float(seconds))

    def blackhole(self, on: bool = True) -> None:
        """Silently drop forwarded bytes while ``on`` (connections stay
        open: the far side sees a stall, not a reset)."""
        self._blackhole = bool(on)

    def corrupt_next(self, n: int = 1) -> None:
        """Corrupt the next ``n`` client→server chunks (XOR the leading 4
        bytes — a frame-length header becomes ≥ 2 GiB, which the server
        rejects as a dead stream rather than parsing garbage)."""
        with self._lock:
            self._corrupt_c2s += int(n)

    def reset_conns(self) -> None:
        """Sever every live connection; keep accepting new ones."""
        with self._lock:
            conns, self._conns = self._conns, []
        for a, b in conns:
            _close(a)
            _close(b)

    def kill(self) -> None:
        """Stop accepting AND sever everything — upstream looks dead."""
        self._killed = True
        _close(self._listener)
        self.reset_conns()

    close = kill

    # -- plumbing ------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._killed:
            try:
                downstream, _addr = self._listener.accept()
            except OSError:
                return
            try:
                upstream = self._dial_upstream()
            except OSError:
                _close(downstream)
                continue
            with self._lock:
                if self._killed:
                    _close(downstream)
                    _close(upstream)
                    return
                self._conns.append((downstream, upstream))
            for src, dst, c2s in ((downstream, upstream, True),
                                  (upstream, downstream, False)):
                threading.Thread(target=self._pump, args=(src, dst, c2s),
                                 name=f"chaos-pump-{self.port}",
                                 daemon=True).start()

    def _dial_upstream(self) -> socket.socket:
        if is_uds(self.upstream_host):
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.connect(uds_path(self.upstream_host))
            return s
        s = socket.create_connection((self.upstream_host,
                                      self.upstream_port), timeout=10.0)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return s

    def _pump(self, src: socket.socket, dst: socket.socket,
              c2s: bool) -> None:
        try:
            while True:
                data = src.recv(_CHUNK)
                if not data:
                    break
                if self._delay:
                    time.sleep(self._delay)
                if self._blackhole:
                    continue                      # bytes vanish
                if c2s and self._corrupt_c2s > 0:
                    with self._lock:
                        take = self._corrupt_c2s > 0
                        if take:
                            self._corrupt_c2s -= 1
                    if take and len(data) >= 4:
                        head = bytes(b ^ 0xFF for b in data[:4])
                        data = head + data[4:]
                dst.sendall(data)
        except OSError:
            pass
        finally:
            _close(src)
            _close(dst)


def kill_shard(handle) -> int:
    """SIGKILL a spawned server's process group (no graceful anything).

    ``handle`` is a ``deploy.ProcHandle`` (or any object with a
    ``.proc.pid``); returns the pid killed.  This is the fault the
    fabric's zero-lost-committed-puts guarantee is tested against.
    """
    pid = handle.proc.pid if hasattr(handle, "proc") else int(handle)
    try:
        os.killpg(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        try:
            os.kill(pid, signal.SIGKILL)
        except ProcessLookupError:
            pass
    if hasattr(handle, "proc"):
        handle.proc.wait(timeout=5)
    return pid


def _close(sock: socket.socket) -> None:
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass
