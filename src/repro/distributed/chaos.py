"""Fault-injection harness for the KV fabric (and anything TCP).

Two layers:

* :class:`ChaosProxy` — a thread-per-connection TCP shim that sits between
  a client and one upstream server and injects faults **on command**:

  - ``kill()``          — stop listening and sever every connection (the
    process-death look-alike for servers you can't SIGKILL, e.g. in-proc);
  - ``blackhole(True)`` — silently drop all forwarded bytes (both
    directions) while leaving connections open: the client sees pure
    timeout, not a reset;
  - ``set_delay(s)``    — sleep ``s`` before forwarding each chunk (ack
    delay / slow-network emulation);
  - ``corrupt_next()``  — XOR the first 4 bytes of the next client→server
    chunk.  Those bytes are a frame-length header, so the server sees a
    length ≥ 2 GiB and must declare the stream dead — the corruption-
    detection path the fabric tests assert on;
  - ``reset_conns()``   — drop live connections but keep listening, so the
    next request exercises the client's transparent-reconnect + retry path
    deterministically.

* :func:`kill_shard` — SIGKILL a spawned server's whole process group: the
  real thing, used by the failover tests and the fig15 recovery benchmark.

* :class:`Partition` — a **symmetric network partition** over a set of
  :class:`ChaosProxy` links: blackholes every link both directions (each
  side of the cut sees the other stall, exactly like a switch dying), and
  heals on ``heal()`` / context-manager exit.

* :class:`FaultSchedule` — a scripted fault sequence on a background
  thread: ``(delay_s, action, label)`` steps fire in order (delays are
  relative to the previous step), recording fired labels.  The factories
  :func:`crash_during_chain_forward` and
  :func:`crash_during_cursor_replication` build the durability-PR
  schedules: arm one, start the put/append storm, and the SIGKILL lands
  while primary→successor chain forwards (or home→replica cursor pushes)
  are in flight — the exact windows the at-least-once guarantee must
  survive.

The proxy listens on loopback TCP and forwards to either a TCP or a
``unix:/path`` upstream, so it can front fabric shards regardless of
transport.  All faults are plain attribute flips — safe to toggle from the
test thread while pumps are mid-transfer.
"""
from __future__ import annotations

import os
import signal
import socket
import threading
import time

from repro.core.kv_tcp import is_uds, uds_path

_CHUNK = 1 << 16


class ChaosProxy:
    """TCP shim with switchable fault injection (see module docstring).

    Usage::

        proxy = ChaosProxy(shard.host, shard.port)
        client = KVClient("127.0.0.1", proxy.port)
        proxy.corrupt_next()
        ...
        proxy.close()
    """

    def __init__(self, upstream_host: str, upstream_port: int = 0,
                 listen_host: str = "127.0.0.1") -> None:
        self.upstream_host = upstream_host
        self.upstream_port = upstream_port
        self._delay = 0.0
        self._blackhole = False
        self._corrupt_c2s = 0            # countdown of chunks to corrupt
        self._killed = False
        self._lock = threading.Lock()
        self._conns: list[tuple[socket.socket, socket.socket]] = []
        self._listener = socket.socket()
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((listen_host, 0))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"chaos-accept-{self.port}",
            daemon=True)
        self._accept_thread.start()

    # -- fault switches ------------------------------------------------------
    def set_delay(self, seconds: float) -> None:
        """Sleep ``seconds`` before forwarding each chunk (both ways)."""
        self._delay = max(0.0, float(seconds))

    def blackhole(self, on: bool = True) -> None:
        """Silently drop forwarded bytes while ``on`` (connections stay
        open: the far side sees a stall, not a reset)."""
        self._blackhole = bool(on)

    def corrupt_next(self, n: int = 1) -> None:
        """Corrupt the next ``n`` client→server chunks (XOR the leading 4
        bytes — a frame-length header becomes ≥ 2 GiB, which the server
        rejects as a dead stream rather than parsing garbage)."""
        with self._lock:
            self._corrupt_c2s += int(n)

    def reset_conns(self) -> None:
        """Sever every live connection; keep accepting new ones."""
        with self._lock:
            conns, self._conns = self._conns, []
        for a, b in conns:
            _close(a)
            _close(b)

    def kill(self) -> None:
        """Stop accepting AND sever everything — upstream looks dead."""
        self._killed = True
        _close(self._listener)
        self.reset_conns()

    close = kill

    # -- plumbing ------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._killed:
            try:
                downstream, _addr = self._listener.accept()
            except OSError:
                return
            try:
                upstream = self._dial_upstream()
            except OSError:
                _close(downstream)
                continue
            with self._lock:
                if self._killed:
                    _close(downstream)
                    _close(upstream)
                    return
                self._conns.append((downstream, upstream))
            for src, dst, c2s in ((downstream, upstream, True),
                                  (upstream, downstream, False)):
                threading.Thread(target=self._pump, args=(src, dst, c2s),
                                 name=f"chaos-pump-{self.port}",
                                 daemon=True).start()

    def _dial_upstream(self) -> socket.socket:
        if is_uds(self.upstream_host):
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.connect(uds_path(self.upstream_host))
            return s
        s = socket.create_connection((self.upstream_host,
                                      self.upstream_port), timeout=10.0)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return s

    def _pump(self, src: socket.socket, dst: socket.socket,
              c2s: bool) -> None:
        try:
            while True:
                data = src.recv(_CHUNK)
                if not data:
                    break
                if self._delay:
                    time.sleep(self._delay)
                if self._blackhole:
                    continue                      # bytes vanish
                if c2s and self._corrupt_c2s > 0:
                    with self._lock:
                        take = self._corrupt_c2s > 0
                        if take:
                            self._corrupt_c2s -= 1
                    if take and len(data) >= 4:
                        head = bytes(b ^ 0xFF for b in data[:4])
                        data = head + data[4:]
                dst.sendall(data)
        except OSError:
            pass
        finally:
            _close(src)
            _close(dst)


def kill_shard(handle) -> int:
    """SIGKILL a spawned server's process group (no graceful anything).

    ``handle`` is a ``deploy.ProcHandle`` (or any object with a
    ``.proc.pid``); returns the pid killed.  This is the fault the
    fabric's zero-lost-committed-puts guarantee is tested against.
    """
    pid = handle.proc.pid if hasattr(handle, "proc") else int(handle)
    try:
        os.killpg(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        try:
            os.kill(pid, signal.SIGKILL)
        except ProcessLookupError:
            pass
    if hasattr(handle, "proc"):
        handle.proc.wait(timeout=5)
    return pid


class Partition:
    """Symmetric network partition across ChaosProxy links.

    ``Partition(p1, p2, ...)`` blackholes every given proxy in both
    directions on ``apply()`` (or ``with`` entry) and restores traffic on
    ``heal()`` (or exit).  Front every shard with a proxy and ring the
    fabric through the proxy addresses, and the links you pass here are
    the cut: shard-to-shard chain forwards crossing it stall exactly like
    client traffic does.

    Usage::

        with Partition(proxy_a, proxy_b):   # the cut is live
            ...                             # puts time out / fail over
        # healed on exit
    """

    def __init__(self, *links: ChaosProxy) -> None:
        self.links = list(links)
        self.active = False

    def apply(self) -> "Partition":
        for p in self.links:
            p.blackhole(True)
        self.active = True
        return self

    def heal(self) -> None:
        for p in self.links:
            p.blackhole(False)
        self.active = False

    __enter__ = apply

    def __exit__(self, *exc) -> None:
        self.heal()


class FaultSchedule:
    """Scripted fault sequence: ``steps`` is a list of ``(delay_s,
    action, label)`` — after ``delay_s`` seconds (relative to the
    previous step) ``action()`` runs and ``label`` is appended to
    ``fired``.  ``start()`` arms it on a daemon thread; ``join()`` waits
    for completion; ``cancel()`` stops unfired steps.  Actions that raise
    still record their label (the kill may race the process exiting on
    its own) — the error lands in ``errors``."""

    def __init__(self, steps) -> None:
        self.steps = list(steps)
        self.fired: list[str] = []
        self.errors: list[tuple[str, Exception]] = []
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "FaultSchedule":
        self._thread = threading.Thread(target=self._run,
                                        name="chaos-schedule", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        for delay, action, label in self.steps:
            if self._stop.wait(float(delay)):
                return
            try:
                action()
            except Exception as e:  # noqa: BLE001 - record, keep going
                self.errors.append((label, e))
            finally:
                self.fired.append(label)

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def cancel(self) -> None:
        self._stop.set()


def crash_during_chain_forward(victim, delay_s: float = 0.05) -> FaultSchedule:
    """Schedule a SIGKILL of ``victim`` (a successor shard's ProcHandle)
    ``delay_s`` after ``start()`` — arm it, then fire a chain-replicated
    put storm so the kill lands while primary→successor forwards are in
    flight.  Committed puts (acked to the client) must survive on the
    primary; unacked ones may fail but must never half-commit."""
    return FaultSchedule([
        (delay_s, lambda: kill_shard(victim), "kill-chain-successor"),
    ]).start()


def crash_during_cursor_replication(victim,
                                    delay_s: float = 0.05) -> FaultSchedule:
    """Schedule a SIGKILL of ``victim`` (a topic's home-shard ProcHandle)
    ``delay_s`` after ``start()`` — arm it, then keep appending/consuming
    so the kill lands between group-state mutations and their replica
    pushes.  After failover the group must resume from its replicated
    cursor: duplicates allowed, skipped events are the bug."""
    return FaultSchedule([
        (delay_s, lambda: kill_shard(victim), "kill-stream-home"),
    ]).start()


def _close(sock: socket.socket) -> None:
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass
