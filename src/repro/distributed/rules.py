"""Per-arch logical-axis rules + parameter/batch/cache PartitionSpecs.

The single rules dict drives everything: activations (via ``shard_as`` inside
model code), parameters, optimizer state, batches and KV caches (via the
PARAM_DIMS name->logical-dims table below).  ``resolve_spec`` silently drops
axes that don't divide, which implements the per-arch fallbacks:

* qwen3-moe: experts (128) % model(16) == 0 -> EP on 'model'; moe_ff stays
  unsharded (axis already used),
* mixtral: experts (8) %% 16 -> dropped; moe_ff (14336) takes 'model' (TP
  inside each expert),
* kv_heads (8) vs model(16) in decode: cache_seq takes 'model' first
  (sequence-sharded decode), kv_heads dropped.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import resolve_spec

# ---------------------------------------------------------------------------
# logical rules
# ---------------------------------------------------------------------------
def make_rules(mesh: Mesh, *, fsdp: bool = True,
               overrides: dict[str, Any] | None = None) -> dict[str, Any]:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp_axes = batch_axes if fsdp else None
    rules: dict[str, Any] = {
        # activations
        "batch": batch_axes,
        "seq": None,
        "act_seq": None,     # residual-stream seq sharding (Megatron-SP) when set to 'model'
        "hd_tp": None,       # KV-cache head_dim sharding (alternative to cache_seq)
        "attn_q": None,      # score-tensor q-position sharding (fixes GQA reshard; §Perf B3)
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "moe_ff": "model",
        "ssm_heads": "model",
        "cache_seq": "model",
        # parameters
        "layers": None,
        "p_embed": fsdp_axes,          # FSDP dim of weight matrices
        "p_heads": "model",            # TP dim of weight matrices
        "p_ff": "model",
        "p_vocab": "model",
        "p_experts": "model",
        "p_moe_ff": "model",
        "p_ssm": "model",
    }
    if overrides:
        rules.update(overrides)
    return rules


# ---------------------------------------------------------------------------
# parameter dims by leaf name (matched against pytree path suffix)
# ---------------------------------------------------------------------------
_2D = {
    "emb": ("p_vocab", "p_embed"),
    "unemb": ("p_embed", "p_vocab"),
    "wq": ("p_embed", "p_heads"),
    "wk": ("p_embed", "p_heads"),
    "wv": ("p_embed", "p_heads"),
    "wo": ("p_heads", "p_embed"),
    "w_up": ("p_embed", "p_ff"),
    "w_gate": ("p_embed", "p_ff"),
    "w_down": ("p_ff", "p_embed"),
    "router": ("p_embed", None),
    "in_proj": ("p_embed", "p_ssm"),
    "out_proj": ("p_ssm", "p_embed"),
    "enc_pos": (None, None),
}
_3D = {  # MoE expert-stacked
    "w_up": ("p_experts", "p_embed", "p_moe_ff"),
    "w_gate": ("p_experts", "p_embed", "p_moe_ff"),
    "w_down": ("p_experts", "p_moe_ff", "p_embed"),
}
_1D = {
    "bq": ("p_heads",), "bk": ("p_heads",), "bv": ("p_heads",),
    "conv_b": ("p_ssm",), "norm_w": ("p_ssm",),
    "a_log": ("p_ssm",), "dt_bias": ("p_ssm",), "d_skip": ("p_ssm",),
}
_2D_OTHER = {"conv_w": (None, "p_ssm")}


def _leaf_dims(path, leaf) -> tuple:
    name = None
    for part in reversed(path):
        if hasattr(part, "key"):
            name = str(part.key)
            break
    nd = leaf.ndim
    in_moe = any(getattr(pp, "key", None) == "moe" for pp in path)
    # per-layer stacking adds a leading 'layers' dim
    def with_layers(dims, rank):
        if len(dims) == rank:
            return dims
        if len(dims) + 1 == rank:
            return ("layers",) + dims
        return (None,) * rank

    if name in _3D and (in_moe or nd >= 3) and name in ("w_up", "w_gate",
                                                        "w_down") and in_moe:
        return with_layers(_3D[name], nd)
    if name in _2D:
        return with_layers(_2D[name], nd)
    if name in _2D_OTHER:
        return with_layers(_2D_OTHER[name], nd)
    if name in _1D:
        return with_layers(_1D[name], nd)
    return (None,) * nd  # norms, scalars, step counters


def tree_specs(mesh: Mesh, rules: dict, tree) -> Any:
    """PartitionSpec tree for a parameter/optimizer pytree."""
    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        dims = _leaf_dims(path, leaf)
        return resolve_spec(mesh, leaf.shape, dims, rules)

    return jax.tree_util.tree_map_with_path(spec, tree)


def tree_shardings(mesh: Mesh, rules: dict, tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(mesh, rules, tree))


# ---------------------------------------------------------------------------
# batch / cache dims
# ---------------------------------------------------------------------------
_BATCH_DIMS = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "vision_emb": ("batch", None, None),
    "frames": ("batch", None, None),
}

_CACHE_DIMS = {
    "k": ("layers", "batch", "cache_seq", "kv_heads", "hd_tp"),
    "v": ("layers", "batch", "cache_seq", "kv_heads", "hd_tp"),
    "ck": ("layers", "batch", None, "kv_heads", None),
    "cv": ("layers", "batch", None, "kv_heads", None),
    "conv": ("layers", "batch", None, "p_ssm"),
    "state": ("layers", "batch", "ssm_heads", None, None),
}


def batch_specs_tree(mesh: Mesh, rules: dict, batch) -> Any:
    return {k: resolve_spec(mesh, v.shape, _BATCH_DIMS[k], rules)
            for k, v in batch.items()}


def cache_specs_tree(mesh: Mesh, rules: dict, cache) -> Any:
    def spec(path, leaf):
        name = str(path[-1].key)
        dims = _CACHE_DIMS[name]
        if len(dims) != leaf.ndim:  # hybrid attn cache: sites leading dim
            dims = (None,) + dims[1:] if leaf.ndim == len(dims) else dims
            dims = dims[:leaf.ndim] if len(dims) > leaf.ndim else \
                dims + (None,) * (leaf.ndim - len(dims))
        return resolve_spec(mesh, leaf.shape, dims, rules)

    return jax.tree_util.tree_map_with_path(spec, cache)
