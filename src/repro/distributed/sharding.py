"""Logical-axis sharding: model code names *logical* dims; a per-arch rules
table maps them to mesh axes (MaxText-style).

    with sharding_rules(mesh, {"batch": ("pod", "data"), "heads": "model", ...}):
        lowered = jax.jit(step, ...).lower(...)

``shard_as(x, *dims)`` is a no-op outside a rules context (smoke tests run on
one device), and silently drops mesh axes that don't divide the dim — that is
what lets e.g. kv_heads=8 fall back gracefully on a 16-way model axis.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current() -> tuple[Mesh, dict[str, Any]] | None:
    return getattr(_STATE, "ctx", None)


@contextmanager
def sharding_rules(mesh: Mesh, rules: dict[str, Any]):
    prev = _current()
    _STATE.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.ctx = prev


def _axes_of(rule) -> tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def resolve_spec(mesh: Mesh, shape: Sequence[int],
                 dims: Sequence[str | None],
                 rules: dict[str, Any]) -> P:
    """Build a PartitionSpec for ``shape`` from logical ``dims``.

    Axes that don't divide their dim are dropped (prefix-wise for composed
    axes); axes may be used at most once across the whole spec.
    """
    used: set[str] = set()
    spec: list[Any] = []
    for size, dim in zip(shape, dims):
        if dim is None:
            spec.append(None)
            continue
        axes = []
        prod = 1
        for ax in _axes_of(rules.get(dim)):
            if ax in used:
                continue
            ax_size = mesh.shape[ax]
            if size % (prod * ax_size) == 0:
                axes.append(ax)
                prod *= ax_size
        used.update(axes)
        spec.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*spec)


def shard_as(x, *dims: str | None):
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(dims) != x.ndim:
        raise ValueError(f"shard_as: {len(dims)} dims for rank-{x.ndim} array")
    spec = resolve_spec(mesh, x.shape, dims, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_for(shape: Sequence[int], dims: Sequence[str | None]) -> P:
    """resolve_spec against the active context (for in/out shardings)."""
    ctx = _current()
    assert ctx is not None, "spec_for requires an active sharding_rules context"
    mesh, rules = ctx
    return resolve_spec(mesh, shape, dims, rules)
