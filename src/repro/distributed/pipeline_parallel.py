"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Microbatches stream through stages connected by ``jax.lax.ppermute`` inside a
``shard_map``; the schedule is the classic (n_micro + n_stages - 1)-tick
pipeline with bubble fraction (S-1)/(M+S-1).

Not part of the assigned 2-axis production mesh (DESIGN.md §5) — provided and
tested (fake 8-device mesh, ``--selftest``) for deployments that add a
'stage' axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
try:  # jax >= 0.5 exports shard_map at top level
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh: Mesh, stage_fn, stage_params, x,
                   *, axis: str = "stage"):
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a microbatch pipeline.

    stage_params: pytree with leading dim S (one slice per stage), sharded
    over ``axis``.  x: (M, mb, ...) microbatches (M total), replicated.
    Returns y with the same shape as x.
    """
    n_stages = mesh.shape[axis]

    def pp(params_local, xs):  # params: (1, ...) slice; xs: (M, mb, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        m = xs.shape[0]
        ticks = m + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, out = carry  # buf: (mb, ...) activation entering this stage
            inject = jnp.where(t < m, xs[jnp.minimum(t, m - 1)], xs[0])
            inp = jnp.where(stage == 0, inject, buf)
            y = stage_fn(params_local, inp)
            # harvest finished microbatch at the last stage
            done_idx = t - (n_stages - 1)
            out = jax.lax.cond(
                jnp.logical_and(stage == n_stages - 1, done_idx >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(done_idx, 0), 0),
                lambda o: o, out)
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, out), None

        buf0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(ticks))
        # out lives on the last stage; broadcast so every shard returns it
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    try:
        wrapped = shard_map(pp, mesh=mesh, in_specs=(pspec, P()),
                            out_specs=P(), check_vma=False)
    except TypeError:  # jax 0.4.x spells the flag check_rep
        wrapped = shard_map(pp, mesh=mesh, in_specs=(pspec, P()),
                            out_specs=P(), check_rep=False)
    return wrapped(stage_params, x)


def _selftest() -> None:
    import os

    assert os.environ.get("XLA_FLAGS", "").find("device_count") >= 0, \
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    key = jax.random.key(0)
    d = 16
    w = jax.random.normal(key, (4, d, d)) * 0.3  # one matrix per stage

    def stage_fn(wi, x):
        return jnp.tanh(x @ wi)

    x = jax.random.normal(jax.random.key(1), (8, 4, d))  # 8 microbatches
    y = pipeline_apply(mesh, stage_fn, w, x)
    # sequential reference
    ref = x
    for i in range(4):
        ref = jnp.tanh(ref @ w[i])
    import numpy as np

    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    print("pipeline_parallel selftest OK")


if __name__ == "__main__":
    _selftest()
