import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: lower tagged variants of the three chosen cells
and record roofline deltas (hypothesis -> change -> before -> after).

    PYTHONPATH=src python -m repro.launch.perf [--only A1,B1] [--force]

Variants (see EXPERIMENTS.md §Perf for the napkin math):

Cell A = llama3-405b x train_4k   (worst roofline fraction; 842 GB/dev)
  A1  act_seq='model'  — Megatron-SP residual stream: the 126-layer scan
      saves one (B/16, 4096, 16384) bf16 carry per layer (~2.1 GB each);
      sharding the seq dim 16-way should cut the stack ~16x.
  A2  A1 + 4 microbatches — activation stack scales ~1/4 again.
  A3  A2 + bf16 optimizer moments — mu/nu 2 bytes: -6.3 GB/dev.

Cell B = qwen2.5-14b x prefill_32k   (most collective-bound: 316 s vs 1.6 s)
  B1  KV-cache layout (cache_seq=None, hd_tp='model') — k/v are computed
      head-dim-sharded (wk columns on 'model'), so writing the cache in the
      same layout removes the per-layer seq-reshard all-to-alls.

Cell C = mixtral-8x7b x train_4k   (MoE; useful ratio 0.25)
  C1  moe_impl='scatter' — dispatch/combine by segment-sum+gather:
      removes ~3 x 2*B*S*E*C*D einsum FLOPs per layer (~26% of layer cost).
  C2  C1 + act_seq='model' — fit memory (61 GB/dev baseline).
"""
import argparse
import json
from pathlib import Path

from repro.launch.dryrun import ARTIFACTS, run_cell
from repro.train.optimizer import OptConfig

PERF_DIR = ARTIFACTS.parent / "perf"

EXPERIMENTS = {
    # --- Cell A: llama3-405b train_4k ---
    "A1": dict(arch="llama3-405b", shape="train_4k",
               rules_overrides={"act_seq": "model"}),
    "A2": dict(arch="llama3-405b", shape="train_4k",
               rules_overrides={"act_seq": "model"}, n_microbatches=4),
    "A3": dict(arch="llama3-405b", shape="train_4k",
               rules_overrides={"act_seq": "model"}, n_microbatches=4,
               opt_cfg=OptConfig(moment_dtype="bfloat16")),
    "A4": dict(arch="llama3-405b", shape="train_4k", n_microbatches=4,
               cfg_overrides={"scan_group": 9},
               opt_cfg=OptConfig(moment_dtype="bfloat16")),
    "A5": dict(arch="llama3-405b", shape="train_4k", n_microbatches=4,
               cfg_overrides={"scan_group": 9}, multi_pod=True,
               opt_cfg=OptConfig(moment_dtype="bfloat16"), skip_cost=True),
    # --- Cell B: qwen2.5-14b prefill_32k ---
    "B1": dict(arch="qwen2.5-14b", shape="prefill_32k",
               rules_overrides={"cache_seq": None, "hd_tp": "model"}),
    # --- Cell C: mixtral-8x7b train_4k ---
    "C1": dict(arch="mixtral-8x7b", shape="train_4k",
               cfg_overrides={"moe_impl": "scatter"}),
    "C2": dict(arch="mixtral-8x7b", shape="train_4k",
               cfg_overrides={"moe_impl": "scatter"},
               rules_overrides={"act_seq": "model"}),
    "B2": dict(arch="qwen2.5-14b", shape="prefill_32k",
               rules_overrides={"cache_seq": None, "hd_tp": "model"},
               cfg_overrides={"attn_chunk": 256}),
    "C3": dict(arch="mixtral-8x7b", shape="train_4k", n_microbatches=4,
               cfg_overrides={"moe_impl": "scatter"}),
    "C4": dict(arch="mixtral-8x7b", shape="train_4k", n_microbatches=8,
               cfg_overrides={"moe_impl": "scatter"}),
    # attn_q: pin score-tensor sharding to query positions (see layers.py)
    "B3": dict(arch="qwen2.5-14b", shape="prefill_32k",
               rules_overrides={"attn_q": "model"}),
    "A6": dict(arch="llama3-405b", shape="train_4k", n_microbatches=4,
               cfg_overrides={"scan_group": 9},
               rules_overrides={"attn_q": "model"},
               opt_cfg=OptConfig(moment_dtype="bfloat16")),
    "C5": dict(arch="mixtral-8x7b", shape="train_4k", n_microbatches=8,
               cfg_overrides={"moe_impl": "scatter"},
               rules_overrides={"attn_q": "model"}),
}


def measure_flash_adjustment(arch: str, shape_name: str,
                             rules_overrides=None) -> dict:
    """Attention's exact HLO contribution via ablation, replaced by the
    Pallas flash kernel's analytic HBM traffic.

    Lowers the L=1 cost variant twice (normal vs attention_impl='ablate');
    the delta IS attention's per-layer FLOPs/bytes in this program.  The
    flash kernel (validated in tests/test_kernels.py) performs the same
    matmul FLOPs but streams only Q/K/V/O through HBM, so:

        adj_bytes = bytes - L*(attn_bytes_delta) + L*flash_bytes_analytic
        adj_flops = flops (unchanged)
    """
    from repro.configs import ARCHS, SHAPES
    from repro.launch.dryrun import (_cost_variant_cfg, _with_depth,
                                     lower_cell)
    from repro.launch.mesh import make_production_mesh

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    base = _with_depth(_cost_variant_cfg(cfg, shape), 1)
    out = {}
    for tag, c in (("normal", base),
                   ("ablate", base.replace(attention_impl="ablate"))):
        comp, _ = lower_cell(c, shape, mesh, rules_overrides=rules_overrides)
        ca = comp.cost_analysis() or {}
        out[tag] = {"flops": ca.get("flops", 0.0),
                    "bytes": ca.get("bytes accessed", 0.0)}
    attn_flops = out["normal"]["flops"] - out["ablate"]["flops"]
    attn_bytes = out["normal"]["bytes"] - out["ablate"]["bytes"]
    # flash HBM traffic per layer per device: read Q,K,V + write O (fwd);
    # bwd ~2x more passes for train
    b, sq = shape.global_batch, shape.seq_len
    dt_bytes = 2
    qo = b * sq * cfg.n_heads * cfg.hd * dt_bytes
    kv = b * sq * cfg.n_kv_heads * cfg.hd * dt_bytes
    passes = 3.0 if shape.kind == "train" else 1.0
    flash_bytes_global = passes * (2 * qo + 2 * kv)
    flash_bytes = flash_bytes_global / mesh.size
    result = {
        "cell": f"{arch}__{shape_name}", "per_layer": out,
        "attn_flops_per_layer_dev": attn_flops,
        "attn_bytes_per_layer_dev": attn_bytes,
        "flash_bytes_per_layer_dev": flash_bytes,
        "bytes_saved_per_layer_dev": attn_bytes - flash_bytes,
    }
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    (PERF_DIR / f"flashadj__{arch}__{shape_name}.json").write_text(
        json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--flash-adjust", default=None,
                    help="arch:shape[:hd] — measure attention ablation")
    args = ap.parse_args()
    if args.flash_adjust:
        parts = args.flash_adjust.split(":")
        ro = None
        if "hd" in parts:
            ro = {"cache_seq": None, "hd_tp": "model"}
        if "attnq" in parts:
            ro = dict(ro or {}, attn_q="model")
        r = measure_flash_adjustment(parts[0], parts[1], rules_overrides=ro)
        print(json.dumps(r, indent=1))
        return
    only = set(args.only.split(",")) if args.only else None

    for name, exp in EXPERIMENTS.items():
        if only and name not in only:
            continue
        kw = dict(exp)
        arch, shape = kw.pop("arch"), kw.pop("shape")
        multi_pod = kw.pop("multi_pod", False)
        skip_cost = kw.pop("skip_cost", args.skip_cost)
        r = run_cell(arch, shape, multi_pod=multi_pod, out_dir=PERF_DIR,
                     force=args.force, skip_cost=skip_cost,
                     tag=f"__{name}", **kw)
        mem = r.get("memory", {}).get("peak_device_bytes", 0)
        ext = r.get("cost_extrapolated", {})
        print(f"{name}: {r['status']} peak={mem/1e9:.1f}GB "
              f"flops/dev={ext.get('flops_per_device', 0):.2e} "
              f"bytes/dev={ext.get('bytes_per_device', 0):.2e} "
              f"coll/dev={ext.get('collective_link_bytes_per_device', 0):.2e}",
              flush=True)


if __name__ == "__main__":
    main()
