"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches JAX device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices; smoke tests and benchmarks see 1.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x; older releases imply Auto axes
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _axis_types_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests use (2,2,2) etc. on fake devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kwargs(len(axes)))
