"""Aggregate §Perf artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.perf_report
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops

ART = Path(__file__).resolve().parents[3] / "artifacts"

CELLS = {
    "A": ("llama3-405b", "train_4k", 126),
    "B": ("qwen2.5-14b", "prefill_32k", 48),
    "C": ("mixtral-8x7b", "train_4k", 32),
}


def load(path, n_micro=1):
    d = json.loads(Path(path).read_text())
    ext = d.get("cost_extrapolated")
    mem = d.get("memory", {}).get("peak_device_bytes", 0)
    if ext is None:
        return {"peak": mem, "flops": None, "bytes": None, "coll": None,
                "n_micro": n_micro}
    return {"peak": mem,
            "flops": ext["flops_per_device"] * n_micro,
            "bytes": ext["bytes_per_device"] * n_micro,
            "coll": ext["collective_link_bytes_per_device"] * n_micro,
            "n_micro": n_micro}


def row(tag, arch, shape_name, m, flash_L=None):
    cfg, shape = ARCHS[arch], SHAPES[shape_name]
    if m["flops"] is None:
        print(f"{tag:28s} peak={m['peak']/1e9:7.1f}GB  (compile-proof only)")
        return
    b = m["bytes"]
    if flash_L:
        adj = json.loads((ART / "perf" /
                          f"flashadj__{arch}__{shape_name}.json").read_text())
        b = b - flash_L * adj["attn_bytes_per_layer_dev"] \
            + flash_L * adj["flash_bytes_per_layer_dev"]
    cs, ms, cls = m["flops"] / PEAK_FLOPS, b / HBM_BW, m["coll"] / LINK_BW
    terms = {"compute": cs, "memory": ms, "collective": cls}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / (m["flops"] * 256)
    mfu = mf / (256 * PEAK_FLOPS * max(terms.values()))
    print(f"{tag:28s} compute={cs:9.2f}s memory={ms:9.2f}s "
          f"coll={cls:9.2f}s bound={dom:10s} useful={useful:5.2f} "
          f"MFU@bound={mfu:6.3f} peak={m['peak']/1e9:7.1f}GB")


def main() -> None:
    for cell, (arch, shape, L) in CELLS.items():
        print(f"--- Cell {cell}: {arch} x {shape} ---")
        base = ART / "dryrun" / f"{arch}__{shape}__single.json"
        row(f"{cell}0 baseline", arch, shape, load(base))
        for v in sorted(ART.glob(f"perf/{arch}__{shape}__*__{cell}*.json")):
            tag = v.stem.split("__")[-1]
            d = json.loads(v.read_text())
            n_micro = d.get("n_microbatches", 1)
            row(f"{tag} {d.get('rules_overrides', {})}"
                f"{d.get('cfg_overrides', {})}"[:40],
                arch, shape, load(v, n_micro))
        fa = ART / "perf" / f"flashadj__{arch}__{shape}.json"
        # flash adjustment is only claimed where the L=1 ablation is
        # self-consistent with the depth-pair increment (cell B; see
        # EXPERIMENTS.md §Perf) — adopted variants: A4 / B3 / C4
        if fa.exists() and cell == "B":
            best = "B3"
            bv = ART / "perf" / f"{arch}__{shape}__single__{best}.json"
            if bv.exists():
                d = json.loads(bv.read_text())
                if d.get("cost_extrapolated"):
                    row(f"{best}+flash-adjusted", arch, shape,
                        load(bv, d.get("n_microbatches", 1)), flash_L=L)
        print()


if __name__ == "__main__":
    main()
