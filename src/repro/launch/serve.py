"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --preset small --requests 8 --new-tokens 32 [--ckpt-dir DIR]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ARCHS
from repro.launch.train import build_cfg
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="small")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore weights from a proxy-checkpoint directory")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.preset)
    ckpts = None
    if args.ckpt_dir:
        from repro.core import Store
        from repro.core.connectors import FileConnector
        from repro.train.checkpoints import ProxyCheckpointManager

        store = Store("serve-ckpts", FileConnector(args.ckpt_dir + "/data"))
        ckpts = ProxyCheckpointManager(store, args.ckpt_dir + "/ckpts")
    engine = ServeEngine(cfg, ckpts=ckpts, max_batch=args.requests)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                             size=args.prompt_len)),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    out = engine.generate(reqs)
    print(json.dumps({
        "arch": cfg.name, "requests": len(reqs),
        "prefill_s": round(out["prefill_s"], 3),
        "decode_s": round(out["decode_s"], 3),
        "tokens_per_s": round(out["tokens_per_s"], 1),
        "sample_output": out["outputs"][0][:16],
    }, indent=1))


if __name__ == "__main__":
    main()
