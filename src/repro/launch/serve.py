"""Serving driver: batch mode, stream mode, and multi-worker weight sharing.

    # batch: generate for N synthetic requests (continuous batching when the
    # family supports it)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --preset small --requests 8 --new-tokens 32 [--ckpt-dir DIR]

    # stream: feed the engine through a ProxyStream (requests -> proxies ->
    # engine; completions -> evict=True proxies -> result stream)
    PYTHONPATH=src python -m repro.launch.serve --stream [--workers N]

``--workers N`` additionally spawns N worker processes that each construct
an engine from a ``borrow()`` of the parent's published weight proxy —
on the shm data plane all N resolve zero-copy views of ONE arena mapping
(no per-worker deep copy of the parameters).
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import tempfile
import threading

import numpy as np

from repro.configs import ARCHS
from repro.launch.train import build_cfg
from repro.serve.engine import Request, ServeEngine


def _build_ckpts(ckpt_dir: str):
    from repro.core import Store
    from repro.core.connectors import FileConnector
    from repro.train.checkpoints import ProxyCheckpointManager

    store = Store("serve-ckpts", FileConnector(ckpt_dir + "/data"))
    return ProxyCheckpointManager(store, ckpt_dir + "/ckpts")


def _worker_main(arch: str, preset: str, borrowed, conn) -> None:
    """A serving worker: builds its engine from a borrowed weight proxy
    (zero-copy views of the publisher's arena slot) and reports how many
    parameter bytes it mapped without copying."""
    cfg = build_cfg(arch, preset)
    engine = ServeEngine(cfg, weights=borrowed, max_batch=2)
    rng = np.random.default_rng(7)
    out = engine.generate([Request(prompt=list(rng.integers(
        1, cfg.vocab, size=8)), max_new_tokens=4)])
    conn.send({"tokens": out["outputs"][0]})
    conn.close()


def _run_workers(args, engine: ServeEngine) -> None:
    from repro.core import Store, borrow
    from repro.core.connectors import SharedMemoryConnector

    reg = tempfile.mkdtemp(prefix="serve-weights-")
    wstore = Store("serve-weights", SharedMemoryConnector(reg))
    owned = engine.publish_weights(wstore, ttl=300.0)
    ctx = mp.get_context("spawn")
    procs, pipes = [], []
    for _ in range(args.workers):
        parent, child = ctx.Pipe()
        p = ctx.Process(target=_worker_main,
                        args=(args.arch, args.preset, borrow(owned), child))
        p.start()
        procs.append(p)
        pipes.append(parent)
    results = [c.recv() for c in pipes]
    for p in procs:
        p.join()
    agree = len({tuple(r["tokens"]) for r in results}) == 1
    print(json.dumps({"workers": args.workers,
                      "outputs_agree": agree,
                      "sample": results[0]["tokens"]}))
    wstore.close()


def _run_stream(args, engine: ServeEngine) -> None:
    from repro.core import Store
    from repro.core.connectors import SharedMemoryConnector

    reg = tempfile.mkdtemp(prefix="serve-stream-")
    store = Store("serve-stream", SharedMemoryConnector(reg))
    rng = np.random.default_rng(0)

    def feed() -> None:
        prod = store.stream_producer("requests")
        for i in range(args.requests):
            prod.append(store.proxy({
                "prompt": list(map(int, rng.integers(
                    1, engine.cfg.vocab, size=args.prompt_len))),
                "max_new_tokens": args.new_tokens,
                "temperature": args.temperature,
                "req_id": f"req-{i}",
            }, evict=True))
        prod.close()

    t = threading.Thread(target=feed)
    t.start()
    stats = engine.serve_stream(store, "requests", "results",
                                data_store=store, timeout=60.0)
    t.join()
    from repro.core.proxy import extract, is_proxy

    results = []
    with store.stream_consumer("results", timeout=10.0) as stream:
        for item in stream:
            results.append(extract(item) if is_proxy(item) else item)
    print(json.dumps({
        "mode": "stream", "served": stats["completed"],
        "decode_steps": stats["decode_steps"],
        "p50_total_s": round(float(np.median(
            [r["total_s"] for r in results])), 4) if results else None,
    }, indent=1))
    store.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="small")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore weights from a proxy-checkpoint directory")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stream", action="store_true",
                    help="feed requests through a ProxyStream instead of a "
                         "static list")
    ap.add_argument("--workers", type=int, default=0,
                    help="spawn N workers sharing the weights zero-copy "
                         "via a borrowed arena proxy")
    ap.add_argument("--max-context", type=int, default=None)
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.preset)
    ckpts = _build_ckpts(args.ckpt_dir) if args.ckpt_dir else None
    max_ctx = args.max_context or (args.prompt_len + args.new_tokens + 8)
    engine = ServeEngine(cfg, ckpts=ckpts, max_batch=args.requests,
                         max_context=max_ctx)

    if args.workers:
        _run_workers(args, engine)
    if args.stream:
        _run_stream(args, engine)
        engine.close()
        return
    if args.workers:
        engine.close()
        return

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                             size=args.prompt_len)),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    out = engine.generate(reqs)
    print(json.dumps({
        "arch": cfg.name, "requests": len(reqs),
        "prefill_s": round(out["prefill_s"], 3),
        "decode_s": round(out["decode_s"], 3),
        "tokens_per_s": round(out["tokens_per_s"], 1),
        "sample_output": out["outputs"][0][:16],
    }, indent=1))
    engine.close()


if __name__ == "__main__":
    main()
