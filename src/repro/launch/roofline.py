"""Roofline analysis from the dry-run artifacts (deliverable g).

Three terms per (arch x shape), single-pod mesh (256 x TPU v5e):

    compute_s    = HLO_FLOPs_per_device  / 197e12      (bf16 peak / chip)
    memory_s     = HLO_bytes_per_device  / 819e9       (HBM BW / chip)
    collective_s = link_bytes_per_device / 50e9        (ICI / link)

HLO numbers come from the scan-corrected cost extrapolation (see
dryrun.py).  MODEL_FLOPS is the analytic "useful work" (6ND convention +
attention/SSD terms); the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/replication waste.

Usage:  PYTHONPATH=src python -m repro.launch.roofline [--json] [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES, shape_applicable

PEAK_FLOPS = 197e12     # bf16 / chip, TPU v5e
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link
HBM_BYTES = 16e9        # v5e HBM capacity
ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (global, per step)
# ---------------------------------------------------------------------------
def _attn_eff_len(cfg, s: int) -> float:
    """Average attended length per query under causal (+window) masking."""
    w = cfg.sliding_window
    if w and s > w:
        return w / 2 + w / 2  # steady state: between w/2 and w; use ~w*0.75
    return s / 2


def model_flops(cfg, shape) -> float:
    n_total, n_active = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    h, hd, lyr = cfg.n_heads, cfg.hd, cfg.n_layers

    def attn_fwd(tokens, kv_len):
        # scores + PV: 2 * 2 * tokens * kv_len * H * HD
        return 4.0 * tokens * kv_len * h * hd * lyr

    def ssd_fwd(tokens):
        if not cfg.ssm_state:
            return 0.0
        q = cfg.ssm_chunk
        hp, n_h, st = cfg.ssm_head_dim, cfg.n_ssm_heads, cfg.ssm_state
        per_tok = (2 * q * st                # CB^T scores row
                   + 2 * q * n_h * hp        # y_diag row
                   + 4 * st * n_h * hp)      # state inject + y_off
        return per_tok * tokens * lyr

    if shape.kind == "train":
        tokens = b * s
        f = 6.0 * n_active * tokens
        if cfg.family == "hybrid":
            sites = (lyr + cfg.attn_every - 1) // cfg.attn_every
            f += 3 * 4.0 * tokens * (s / 2) * h * hd * sites
            f += 3 * ssd_fwd(tokens)
        elif cfg.family == "ssm":
            f += 3 * ssd_fwd(tokens)
        elif cfg.family == "audio":
            f += 3 * attn_fwd(tokens, s / 2)                       # self
            f += 3 * 4.0 * tokens * cfg.enc_frames * h * hd * lyr  # cross
            f += 3 * 4.0 * b * cfg.enc_frames * (cfg.enc_frames / 2) \
                * h * hd * cfg.n_enc_layers
        else:
            f += 3 * attn_fwd(tokens, _attn_eff_len(cfg, s))
        return f
    if shape.kind == "prefill":
        tokens = b * s
        f = 2.0 * n_active * tokens
        if cfg.family == "hybrid":
            sites = (lyr + cfg.attn_every - 1) // cfg.attn_every
            f += 4.0 * tokens * (s / 2) * h * hd * sites + ssd_fwd(tokens)
        elif cfg.family == "ssm":
            f += ssd_fwd(tokens)
        elif cfg.family == "audio":
            f += attn_fwd(tokens, s / 2)
            f += 4.0 * tokens * cfg.enc_frames * h * hd * lyr
        else:
            f += attn_fwd(tokens, _attn_eff_len(cfg, s))
        return f
    # decode: one token per sequence
    f = 2.0 * n_active * b
    cache = min(s, cfg.sliding_window) if cfg.sliding_window else s
    if cfg.family in ("ssm", "hybrid"):
        hp, n_h, st = cfg.ssm_head_dim, cfg.n_ssm_heads, cfg.ssm_state
        f += 4.0 * b * st * n_h * hp * lyr
        if cfg.family == "hybrid":
            sites = (lyr + cfg.attn_every - 1) // cfg.attn_every
            f += 4.0 * b * cache * h * hd * sites
    elif cfg.family == "audio":
        f += 4.0 * b * cache * h * hd * lyr
        f += 4.0 * b * cfg.enc_frames * h * hd * lyr
    else:
        f += 4.0 * b * cache * h * hd * lyr
    return f


# ---------------------------------------------------------------------------
def analyze_cell(path: Path) -> dict | None:
    d = json.loads(path.read_text())
    if d.get("status") != "ok" or "cost_extrapolated" not in d:
        return d if d.get("status") == "skipped" else None
    cfg = ARCHS[d["arch"]]
    shape = SHAPES[d["shape"]]
    ext = d["cost_extrapolated"]
    n_dev = d["n_devices"]

    compute_s = ext["flops_per_device"] / PEAK_FLOPS
    memory_s = ext["bytes_per_device"] / HBM_BW
    coll_s = ext["collective_link_bytes_per_device"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())

    mf = model_flops(cfg, shape)
    hlo_global = ext["flops_per_device"] * n_dev
    useful_ratio = mf / hlo_global if hlo_global else 0.0
    # achievable MFU if the dominant term were the only cost
    mfu_bound = mf / (n_dev * PEAK_FLOPS * bound_s) if bound_s else 0.0

    peak_mem = d["memory"]["peak_device_bytes"]
    return {
        "cell": d["cell"], "arch": d["arch"], "shape": d["shape"],
        "kind": d["kind"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": useful_ratio, "roofline_mfu": mfu_bound,
        "peak_device_gb": peak_mem / 1e9,
        "fits_hbm": peak_mem <= HBM_BYTES,
        "compile_s": d.get("compile_s"),
    }


def recommendation(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("shrink collective bytes: reshard to cut all-gathers "
                "(FSDP off / different TP split) or overlap via async "
                "collectives")
    if d == "memory":
        if row["kind"] == "decode":
            return ("decode is HBM-bound on KV/state reads: quantize cache "
                    "to int8 or shard cache_seq wider")
        return ("cut bytes: fuse attention (flash kernel), reduce remat "
                "recompute, or bf16-ize fp32 intermediates")
    if row["useful_ratio"] < 0.5:
        return ("compute-bound but <50% useful: remove replicated/remat "
                "FLOPs (check einsum partitioning)")
    return "near compute roofline: raise per-device batch or fuse elementwise"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows, skips = [], []
    for arch in ARCHS:
        for shape in SHAPES:
            p = ARTIFACTS / f"{arch}__{shape}__{args.mesh}.json"
            if not p.exists():
                continue
            r = analyze_cell(p)
            if r is None:
                continue
            if r.get("status") == "skipped":
                skips.append(f"{arch} x {shape}: {r['reason']}")
            else:
                rows.append(r)

    if args.json:
        print(json.dumps(rows, indent=1))
        return

    hdr = (f"| {'arch':22s} | {'shape':11s} | {'compute':>9s} | "
           f"{'memory':>9s} | {'collective':>10s} | {'bound':>10s} | "
           f"{'useful':>6s} | {'MFU@bound':>9s} | {'GB/dev':>6s} |")
    sep = "|" + "|".join("-" * (len(c) + 2) for c in
                         hdr.strip("|").split("|")) + "|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']:22s} | {r['shape']:11s} "
            f"| {r['compute_s']*1e3:8.1f}ms | {r['memory_s']*1e3:8.1f}ms "
            f"| {r['collective_s']*1e3:9.1f}ms | {r['dominant']:>10s} "
            f"| {r['useful_ratio']:6.2f} | {r['roofline_mfu']:9.2f} "
            f"| {r['peak_device_gb']:6.2f} |")
    table = "\n".join(lines)
    print(table)
    print("\nSkipped cells:")
    for s in skips:
        print("  -", s)
    if args.out:
        Path(args.out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
