"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --steps 200 --preset small --workdir /tmp/run1 [--resume]

Presets scale the assigned architecture down for CPU execution while keeping
its family structure (the full configs are exercised by the dry-run).
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCHS
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    # ~2M params: CI-speed smoke of the full loop
    "tiny": dict(n_layers=2, d_model=128, d_ff=256, vocab=512),
    # ~20M params: default e2e demo
    "small": dict(n_layers=4, d_model=384, d_ff=1024, vocab=4096),
    # ~100M params: the deliverable-scale run (slow on 1 CPU core)
    "100m": dict(n_layers=12, d_model=768, d_ff=2048, vocab=32000),
    "full": {},
}


def build_cfg(arch: str, preset: str):
    cfg = ARCHS[arch]
    if preset == "full":
        return cfg
    red = cfg.reduced()
    kw = dict(PRESETS[preset])
    if cfg.n_heads:
        kw.update(n_heads=min(cfg.n_heads, 8), n_kv_heads=min(cfg.n_kv_heads, 4),
                  head_dim=kw.get("d_model", 128) // min(cfg.n_heads, 8))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=64)
    if cfg.family == "moe":
        kw.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                  moe_d_ff=kw.get("d_ff", 256) // 2)
    kw["name"] = f"{cfg.name}-{preset}"
    return red.replace(**kw)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=None)
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.preset)
    tc = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                     workdir=args.workdir, resume=args.resume,
                     ckpt_every=args.ckpt_every, crash_at_step=args.crash_at)
    opt = OptConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    decay_steps=args.steps)
    trainer = Trainer(cfg, tc, opt)
    result = trainer.run()
    print(json.dumps({"arch": cfg.name,
                      "final_loss": result["final_loss"],
                      "pipeline": result["pipeline"]}, indent=1))


if __name__ == "__main__":
    main()
