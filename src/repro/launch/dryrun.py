import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (artifacts/<arch>__<shape>__<mesh>.json):

1. REAL variant — the production config (scan-over-layers, chunked attention/
   loss).  Its successful ``.lower().compile()`` is the deliverable proof;
   ``memory_analysis()`` gives per-device bytes; its HLO text gives the
   collective op census.
2. COST variant — same shardings, ``scan_layers=False`` and unchunked
   attention/loss, lowered at n_layers = {k, 2k}.  XLA's cost analysis counts
   a while-loop body ONCE regardless of trip count (verified empirically), so
   scanned programs under-report; the unrolled 1/2-layer pair gives an exact
   per-layer delta to extrapolate FLOPs / bytes / collective-bytes to the
   full depth:  total(L) = base(k) + (L-k)/k * delta.

Roofline terms are then derived in launch/roofline.py from these artifacts.
"""
import argparse
import dataclasses
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.distributed.rules import (batch_specs_tree, cache_specs_tree,
                                     make_rules, tree_specs)
from repro.distributed.sharding import sharding_rules
from repro.launch.mesh import make_production_mesh
from repro.models.model import abstract_params, cache_specs, input_specs
from repro.train.optimizer import OptConfig
from repro.train.train_step import (abstract_train_state, make_prefill_step,
                                    make_serve_step, make_train_step)

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# HLO collective census
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_census(hlo_text: str) -> dict:
    """Per-op counts and estimated per-device link bytes.

    Ring estimates with group size g: all-gather/all-to-all: out*(g-1)/g;
    all-reduce: 2*out*(g-1)/g; reduce-scatter: out*(g-1) (out is the shard);
    collective-permute: out.
    """
    census: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        out_bytes = _shape_bytes(shape_str)
        g = 0
        mi = _GROUPS_IOTA_RE.search(line)
        if mi:
            g = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml:
                g = len(ml.group(1).split(","))
        g = max(g, 2)
        if op == "all-reduce":
            link = 2 * out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            link = out_bytes * (g - 1)
        elif op == "collective-permute":
            link = out_bytes
        else:  # all-gather, all-to-all
            link = out_bytes * (g - 1) / g
        c = census.setdefault(op, {"count": 0, "bytes": 0.0, "link_bytes": 0.0})
        c["count"] += 1
        c["bytes"] += out_bytes
        c["link_bytes"] += link
    return census


def census_total(census: dict) -> float:
    return sum(c["link_bytes"] for c in census.values())


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------
def _shardings_for(mesh, rules, cfg, shape, kind, opt_cfg):
    """(in_shardings, out_shardings, donate, abstract_args, step_fn)."""
    from repro.distributed.sharding import resolve_spec

    ns = lambda spec: NamedSharding(mesh, spec)
    b = shape.global_batch
    logits_sh = ns(resolve_spec(mesh, (b, cfg.padded_vocab),
                                ("batch", "vocab"), rules))
    token_sh = ns(resolve_spec(mesh, (b, 1), ("batch", None), rules))

    if kind == "train":
        step = make_train_step(cfg, opt_cfg,
                               n_microbatches=getattr(opt_cfg, "_n_micro", 1))
        state = abstract_train_state(cfg, opt_cfg)
        batch = input_specs(cfg, shape)["batch"]
        state_sh = jax.tree.map(ns, tree_specs(mesh, rules, state))
        batch_sh = jax.tree.map(ns, batch_specs_tree(mesh, rules, batch))
        metric_sh = {k: ns(P()) for k in
                     ("loss", "nll", "grad_norm", "lr", "lb_loss", "z_loss")}
        metric_sh = None  # let XLA infer scalar outputs
        return (dict(fn=step, args=(state, batch),
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate=(0,)))
    if kind == "prefill":
        step = make_prefill_step(cfg)
        params = abstract_params(cfg)
        batch = input_specs(cfg, shape)["batch"]
        params_sh = jax.tree.map(ns, tree_specs(mesh, rules, params))
        batch_sh = jax.tree.map(ns, batch_specs_tree(mesh, rules, batch))
        cache = cache_specs(cfg, shape)
        cache_sh = jax.tree.map(ns, cache_specs_tree(mesh, rules, cache))
        return (dict(fn=step, args=(params, batch),
                     in_shardings=(params_sh, batch_sh),
                     out_shardings=(logits_sh, cache_sh), donate=()))
    if kind == "decode":
        step = make_serve_step(cfg)
        params = abstract_params(cfg)
        spec = input_specs(cfg, shape)
        cache, token, pos = spec["cache"], spec["token"], spec["pos"]
        params_sh = jax.tree.map(ns, tree_specs(mesh, rules, params))
        cache_sh = jax.tree.map(ns, cache_specs_tree(mesh, rules, cache))
        pos_sh = ns(P())
        return (dict(fn=step, args=(params, cache, token, pos),
                     in_shardings=(params_sh, cache_sh, token_sh, pos_sh),
                     out_shardings=(logits_sh, cache_sh), donate=(1,)))
    raise ValueError(kind)


def lower_cell(cfg, shape, mesh, *, opt_cfg=None, rules_overrides=None,
               fsdp=True, n_microbatches=1):
    """Lower + compile one cell; returns (compiled, seconds, spec_dict)."""
    opt_cfg = opt_cfg or OptConfig()
    object.__setattr__(opt_cfg, "_n_micro", n_microbatches) \
        if n_microbatches != 1 else None
    rules = make_rules(mesh, fsdp=fsdp, overrides=rules_overrides)
    with sharding_rules(mesh, rules):
        spec = _shardings_for(mesh, rules, cfg, shape, shape.kind, opt_cfg)
        t0 = time.perf_counter()
        jitted = jax.jit(spec["fn"], in_shardings=spec["in_shardings"],
                         out_shardings=spec["out_shardings"],
                         donate_argnums=spec["donate"])
        lowered = jitted.lower(*spec["args"])
        compiled = lowered.compile()
        dt = time.perf_counter() - t0
    return compiled, dt


def _cost_variant_cfg(cfg, shape):
    """Fully-unrolled config for exact cost analysis.

    Layers, attention chunks and loss chunks all become straight-line HLO
    (no while loops), but keep the REAL chunked shapes — replacing chunked
    attention with one full S^2 einsum (the first version of this harness)
    let the SPMD partitioner reshard the giant score tensor, inflating the
    collective term ~300x vs the real program (documented §Perf B).
    """
    return cfg.replace(scan_layers=False, attn_unroll=True,
                       loss_unroll=True, remat="none")


def _depth_pair(cfg):
    k = cfg.attn_every if cfg.family == "hybrid" else 1
    if cfg.family == "audio":
        return k, 2 * k
    return k, 2 * k


def _with_depth(cfg, n):
    kw = {"n_layers": n}
    if cfg.family == "audio":
        kw["n_enc_layers"] = n
    return cfg.replace(**kw)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: Path = ARTIFACTS, force: bool = False,
             skip_cost: bool = False, fsdp: bool = True,
             rules_overrides=None, tag: str = "",
             cfg_overrides=None, opt_cfg=None,
             n_microbatches: int = 1) -> dict:
    cfg = ARCHS[arch_name]
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch_name}__{shape_name}__{mesh_name}{tag}"
    out_path = out_dir / f"{cell_id}.json"
    out_dir.mkdir(parents=True, exist_ok=True)
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result = {"cell": cell_id, "status": "skipped", "reason": why}
        out_path.write_text(json.dumps(result, indent=1))
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    result = {"cell": cell_id, "arch": arch_name, "shape": shape_name,
              "cfg_overrides": cfg_overrides or {},
              "rules_overrides": {k: str(v) for k, v in (rules_overrides or {}).items()},
              "n_microbatches": n_microbatches,
              "mesh": list(mesh.shape.values()), "n_devices": n_dev,
              "kind": shape.kind, "status": "ok", "fsdp": fsdp}
    try:
        # ---- REAL variant: compile proof + memory + collective census ----
        compiled, secs = lower_cell(cfg, shape, mesh, fsdp=fsdp,
                                    rules_overrides=rules_overrides,
                                    opt_cfg=opt_cfg,
                                    n_microbatches=n_microbatches)
        ma = compiled.memory_analysis()
        result["compile_s"] = round(secs, 2)
        result["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        }
        ca = compiled.cost_analysis() or {}
        result["cost_scanned"] = {"flops": ca.get("flops", 0.0),
                                  "bytes": ca.get("bytes accessed", 0.0)}
        census = collective_census(compiled.as_text())
        result["collectives_scanned"] = census

        if not skip_cost:
            # ---- COST variant: unrolled depth pair -> per-layer delta ----
            ka, kb = _depth_pair(cfg)
            costs = {}
            for n in (ka, kb):
                ccfg = _with_depth(_cost_variant_cfg(cfg, shape), n)
                comp, _ = lower_cell(ccfg, shape, mesh, fsdp=fsdp,
                                     rules_overrides=rules_overrides,
                                     opt_cfg=opt_cfg,
                                     n_microbatches=n_microbatches)
                c = comp.cost_analysis() or {}
                costs[n] = {"flops": c.get("flops", 0.0),
                            "bytes": c.get("bytes accessed", 0.0),
                            "coll": census_total(
                                collective_census(comp.as_text()))}
            L = cfg.n_layers
            scale = (L - ka) / (kb - ka)
            ext = {}
            for key in ("flops", "bytes", "coll"):
                delta = costs[kb][key] - costs[ka][key]
                ext[key] = costs[ka][key] + scale * delta
            result["cost_extrapolated"] = {
                "flops_per_device": ext["flops"],
                "bytes_per_device": ext["bytes"],
                "collective_link_bytes_per_device": ext["coll"],
                "depth_pair": [ka, kb],
            }
    except Exception as e:  # noqa: BLE001 - record the failure, keep matrix
        result["status"] = "failed"
        result["error"] = f"{type(e).__name__}: {e}"[:2000]
    out_path.write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.perf_counter()
                r = run_cell(arch, shape, mp, out_dir=Path(args.out),
                             force=args.force, skip_cost=args.skip_cost,
                             fsdp=not args.no_fsdp)
                mem = r.get("memory", {}).get("peak_device_bytes")
                print(f"{r['cell']:58s} {r['status']:8s} "
                      f"peak={mem/1e9:.2f}GB " if mem else
                      f"{r['cell']:58s} {r['status']:8s} ",
                      f"({time.perf_counter()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
