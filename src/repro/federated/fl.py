"""Federated learning orchestrator (paper §5.5, Fig 10 — FLoX analog).

An aggregator drives rounds of local training on edge workers executed
through the FaaS executor (payload-capped cloud control plane, as in the
paper).  Data movement is pluggable:

* ``transport="value"`` — the baseline: model weights ride the FaaS payload
  (fails beyond the cap as model size grows; Fig 10's truncated baseline),
* ``transport="proxy"`` — weights go through a Store once per round; workers
  receive a ~300-byte proxy and resolve just-in-time; updates return by
  proxy too,
* ``pipeline=True`` (with ``transport="proxy"``) — the futures + streaming
  mode: the aggregator mints every round's weight :class:`ProxyFuture`
  upfront and dispatches round ``r+1``'s workers with a *pre-data* proxy
  BEFORE round ``r``'s aggregation finishes (they park in the channel's
  ``wait`` and are released by ``set_result``), and workers stream their
  updates (``Store.stream_producer``) instead of barrier-putting — the
  aggregator consumes updates as they land, overlapping collection with
  stragglers and dispatch with aggregation.

Round data uses the ownership subsystem (``Store.owned_proxy``): the round's
weights are an :class:`~repro.core.OwnedProxy` — every worker submit clones a
reference, each worker drops its reference after materializing the weights,
and the aggregator drops its own at round end, so the key is evicted exactly
once, after the LAST consumer (stragglers past the deadline still resolve
safely instead of hitting the old evict race).  A TTL lease bounds leaks from
workers that crash while holding references.  Pipelined-round weights are
future-backed plain proxies under a TTL lease; streamed updates are
refcounted stream items (consumed exactly once) under the same lease
backstop.

Production FL features: update compression (int8/topk + error feedback),
round deadlines with straggler dropping, worker failure injection +
over-provisioning, elastic worker counts per round, heartbeats.
"""
from __future__ import annotations

import math
import pickle
import random
import time
from concurrent.futures import FIRST_COMPLETED, wait
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import Store
from repro.core.proxy import extract, is_proxy, release
from repro.core.store import StoreConfig, get_or_create_store
from repro.data.datasets import lm_batch
from repro.distributed.compression import Compressor
from repro.federated.faas import FaasExecutor, PayloadTooLarge


@dataclass
class FLConfig:
    rounds: int = 3
    workers_per_round: int = 4
    local_steps: int = 4
    batch: int = 4
    seq: int = 32
    lr: float = 0.05
    transport: str = "proxy"          # proxy | value
    pipeline: bool = False            # futures + streamed updates
    compression: str = "none"         # none | int8 | int8_ef | topk
    deadline_s: float = 60.0
    fail_rate: float = 0.0            # injected worker failures
    seed: int = 0


# ---------------------------------------------------------------------------
# worker task (module-level: picklable by reference for spawn workers)
# ---------------------------------------------------------------------------
def local_train_task(model_ref: Any, cfg: ArchConfig, fl_blob: bytes,
                     worker_seed: int, store_cfg_blob: bytes | None,
                     compression: str, stream_topic: str | None = None) -> Any:
    fl: FLConfig = pickle.loads(fl_blob)
    store = (get_or_create_store(pickle.loads(store_cfg_blob))
             if store_cfg_blob is not None else None)
    # streamed-update mode: the update goes out through the round's stream
    # as soon as it exists; failures go out the same way (in order), so the
    # aggregator never stalls waiting for a worker that already died
    producer = (store.stream_producer(stream_topic, ttl=4 * fl.deadline_s)
                if store is not None and stream_topic else None)
    try:
        if fl.fail_rate and random.random() < fl.fail_rate:
            raise RuntimeError(f"injected worker failure (seed {worker_seed})")

        if is_proxy(model_ref):
            # pre-data round weights (pipeline mode) park here in wait
            # until the aggregator's set_result lands them
            params = jax.tree.map(np.asarray, extract(model_ref))
            release(model_ref)  # weights materialized: drop worker's ref
        else:
            params = jax.tree.map(np.asarray, model_ref)

        from repro.models.model import build_model

        model = build_model(cfg)

        def loss_fn(p, batch):
            return model.loss(p, batch)[0]

        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        p = jax.tree.map(jax.numpy.asarray, params)
        for step in range(fl.local_steps):
            batch = lm_batch(worker_seed, step, fl.batch, fl.seq, cfg.vocab)
            _, g = grad_fn(p, {k: jax.numpy.asarray(v)
                               for k, v in batch.items()})
            p = jax.tree.map(lambda w, gg: (w.astype(np.float32)
                                            - fl.lr * gg.astype(np.float32)
                                            ).astype(w.dtype), p, g)
        update = jax.tree.map(
            lambda new, old: np.asarray(new, np.float32)
            - np.asarray(old, np.float32), p, params)
        if compression != "none":
            update = Compressor(compression).compress(update)
        if producer is not None:
            # meta rides the broker (not the data plane): a payload=False
            # monitor group can tail who delivered what without resolving
            # a single update payload
            return {"streamed": producer.append(
                update, meta={"worker": worker_seed, "ok": True})}
        if store is not None:
            # owned reference back: the aggregator releases it after
            # averaging; the lease reaps it if the aggregator dies first
            return store.owned_proxy(update, ttl=4 * fl.deadline_s)
        return update
    except Exception as e:
        if producer is not None:
            try:
                producer.append_exception(     # the aggregator counts it
                    e, meta={"worker": worker_seed, "ok": False})
            except Exception:  # noqa: BLE001 - stream already closed (the
                pass           # round's deadline passed): don't mask `e`
        raise


class FLOrchestrator:
    def __init__(self, cfg: ArchConfig, fl: FLConfig,
                 executor: FaasExecutor, store: Store | None,
                 monitor_group: str | None = None) -> None:
        self.cfg, self.fl = cfg, fl
        self.executor = executor
        self.store = store
        # pipelined rounds only: a second consumer group pre-subscribed on
        # every round's update stream, so a dashboard can tail worker
        # updates without stealing them from the aggregator (see
        # monitor_updates())
        self.monitor_group = monitor_group
        self._round_topics: list[str] = []
        from repro.models.model import build_model

        self.model = build_model(cfg)
        self.params = jax.tree.map(np.asarray,
                                   self.model.init(jax.random.key(fl.seed)))
        self.log: list[dict] = []

    # ------------------------------------------------------------------
    def _dispatch_model(self):
        if self.fl.transport == "proxy":
            assert self.store is not None
            # ONE put per round; every worker submit clones a reference and
            # the round's weights die after the LAST consumer drops it (the
            # lease reaps them if workers crash holding references)
            return self.store.owned_proxy(self.params,
                                          ttl=4 * self.fl.deadline_s)
        return self.params                         # by value (cap applies)

    def run_round(self, rnd: int, n_workers: int | None = None) -> dict:
        fl = self.fl
        n = n_workers or fl.workers_per_round
        model_ref = self._dispatch_model()
        store_blob = pickle.dumps(self.store.config()) \
            if fl.transport == "proxy" else None
        fl_blob = pickle.dumps(fl)
        t0 = time.perf_counter()
        futures = {}
        for w in range(n):
            fut = self.executor.submit(
                local_train_task, model_ref, self.cfg, fl_blob,
                1000 * rnd + w, store_blob, fl.compression)
            futures[fut] = w
        done, not_done = wait(list(futures), timeout=fl.deadline_s)
        updates, failures = [], 0
        for fut in done:
            try:
                result = fut.result()
                if is_proxy(result):
                    payload = extract(result)
                    release(result)   # drop the aggregator's reference
                else:
                    payload = result
                updates.append(Compressor.decompress(payload))
            except (RuntimeError, PayloadTooLarge):
                failures += 1
        stragglers = len(not_done)
        if updates:
            mean_update = jax.tree.map(
                lambda *us: np.mean(np.stack(us), axis=0), *updates)
            self.params = jax.tree.map(
                lambda p, u: (p.astype(np.float32) + u).astype(p.dtype),
                self.params, mean_update)
        if is_proxy(model_ref):  # round over: drop the aggregator's ref —
            release(model_ref)   # eviction happens after the LAST worker's
        info = {"round": rnd, "workers": n, "ok": len(updates),
                "failures": failures, "stragglers": stragglers,
                "wall_s": time.perf_counter() - t0}
        self.log.append(info)
        return info

    # ------------------------------------------------------------------
    # pipelined rounds: pre-data weight futures + streamed updates
    # ------------------------------------------------------------------
    def _dispatch_round(self, rnd: int, model_ref: Any, topic: str,
                        n: int) -> list:
        fl_blob = pickle.dumps(self.fl)
        store_blob = pickle.dumps(self.store.config())
        return [self.executor.submit(
            local_train_task, model_ref, self.cfg, fl_blob,
            1000 * rnd + w, store_blob, self.fl.compression, topic)
            for w in range(n)]

    def _consume_updates(self, topic: str, n: int) -> tuple[list, int, int]:
        """Take ``n`` streamed updates as they land (no barrier): worker
        failures arrive in-stream (``append_exception``) and are counted;
        workers that haven't appended when the ROUND deadline passes are
        stragglers (the deadline bounds the round, not each item)."""
        deadline = time.monotonic() + self.fl.deadline_s
        stream = self.store.stream_consumer(topic, group="aggregator",
                                            timeout=self.fl.deadline_s)
        updates, failures = [], 0
        try:
            for _ in range(n):
                remaining = deadline - time.monotonic()
                if remaining <= 0 and not stream.pending():
                    # past the deadline, but DRAIN prefetched updates
                    # first: they are already taken for this group
                    break
                stream.timeout = max(remaining, 0.05)  # per blocking next
                try:
                    updates.append(Compressor.decompress(next(stream)))
                except StopIteration:
                    break
                except TimeoutError:
                    break
                except Exception:  # noqa: BLE001 - worker's streamed failure
                    failures += 1
        finally:
            # flush acks; requeue anything prefetched-but-undelivered so
            # the group (and the payload refcounts) stay consistent
            stream.close()
            self.store.connector.stream_close(topic)  # reject late appends
        stragglers = n - len(updates) - failures
        return updates, failures, stragglers

    @staticmethod
    def _streams_cross_process(conn) -> bool:
        """True when the connector's streams live on a server (visible to
        worker PROCESSES), not in the in-process fallback table."""
        from repro.core.connector import BaseConnector

        child = getattr(conn, "_future_child", None)
        if child is not None:            # MultiConnector: ask its route
            return FLOrchestrator._streams_cross_process(child()[1])
        return type(conn).stream_next is not BaseConnector.stream_next

    def _run_pipelined(self, worker_schedule: list[int] | None) -> dict:
        """Rounds overlap: every round's weight future is minted upfront,
        round ``r+1``'s workers are dispatched (with a pre-data proxy)
        BEFORE round ``r``'s updates are aggregated, and ``set_result``
        releases them once the new weights exist.  Workers stream updates
        the moment they finish, so collection overlaps the stragglers."""
        fl = self.fl
        assert self.store is not None, "pipeline mode needs a store"
        if not self._streams_cross_process(self.store.connector):
            # the fallback stream table is process-local: worker processes
            # would append into their own tables and every round would
            # silently time out with zero updates
            raise ValueError(
                "pipeline=True needs a server-backed store connector "
                "(kvserver/socket/endpoint) — "
                f"{type(self.store.connector).__name__} streams are "
                "in-process only")
        run_id = f"fl-{id(self) & 0xffffff:x}-{random.randrange(1 << 24):x}"
        counts = [worker_schedule[r] if worker_schedule
                  else fl.workers_per_round for r in range(fl.rounds)]
        topics = [f"{run_id}-r{r}" for r in range(fl.rounds)]
        self._round_topics = topics
        if self.monitor_group:
            # pre-subscribe the monitor on every round's topic BEFORE any
            # worker appends: each update is then retained until BOTH the
            # aggregator and the monitor ack it, so tailing the stream
            # steals nothing from aggregation (updates publish once; the
            # producer's TTL lease backstops a monitor that never drains)
            for t in topics:
                self.store.connector.stream_subscribe(
                    t, self.monitor_group, start="begin")
        # every round's weights exist as a future BEFORE any aggregation
        weight_futs = [self.store.future(timeout=4 * fl.deadline_s,
                                         ttl=8 * fl.deadline_s)
                       for _ in range(fl.rounds)]
        weight_futs[0].set_result(self.params)
        losses = [self.eval_loss()]
        self._dispatch_round(0, weight_futs[0].proxy(), topics[0], counts[0])
        for rnd in range(fl.rounds):
            t0 = time.perf_counter()
            if rnd + 1 < fl.rounds:
                # next round goes out NOW: its workers transit the cloud
                # hop and park in wait while this round aggregates
                self._dispatch_round(rnd + 1, weight_futs[rnd + 1].proxy(),
                                     topics[rnd + 1], counts[rnd + 1])
            updates, failures, stragglers = self._consume_updates(
                topics[rnd], counts[rnd])
            if updates:
                mean_update = jax.tree.map(
                    lambda *us: np.mean(np.stack(us), axis=0), *updates)
                self.params = jax.tree.map(
                    lambda p, u: (p.astype(np.float32) + u).astype(p.dtype),
                    self.params, mean_update)
            if rnd + 1 < fl.rounds:
                weight_futs[rnd + 1].set_result(self.params)  # release them
            info = {"round": rnd, "workers": counts[rnd],
                    "ok": len(updates), "failures": failures,
                    "stragglers": stragglers,
                    "wall_s": time.perf_counter() - t0}
            self.log.append(info)
            losses.append(self.eval_loss())
        return {"losses": losses, "rounds": self.log}

    def monitor_updates(self, rnd: int, *, payload: bool = False,
                        timeout: float = 5.0):
        """Consumer tailing round ``rnd``'s update stream in the monitor
        group (pipelined runs with ``monitor_group`` set).  Defaults to
        ``payload=False``: iteration yields each update's metadata
        (``worker``/``ok``) without resolving the update tensors, so a
        live dashboard costs zero data-plane bytes.  The group's cursor
        is independent of the aggregator's — taking here steals nothing
        from aggregation.  Close (or ``with``) the returned consumer."""
        if not self.monitor_group:
            raise ValueError("orchestrator was built without monitor_group")
        if rnd >= len(self._round_topics):
            raise IndexError(f"round {rnd} has not been dispatched")
        return self.store.stream_consumer(
            self._round_topics[rnd], group=self.monitor_group,
            start="begin", payload=payload, timeout=timeout)

    def eval_loss(self) -> float:
        batch = lm_batch(999, 0, self.fl.batch, self.fl.seq, self.cfg.vocab)
        p = jax.tree.map(jax.numpy.asarray, self.params)
        loss, _ = self.model.loss(p, {k: jax.numpy.asarray(v)
                                      for k, v in batch.items()})
        return float(np.asarray(loss))

    def run(self, worker_schedule: list[int] | None = None) -> dict:
        if self.fl.pipeline:
            if self.fl.transport != "proxy":
                raise ValueError("pipeline=True requires transport='proxy'")
            return self._run_pipelined(worker_schedule)
        losses = [self.eval_loss()]
        for rnd in range(self.fl.rounds):
            n = worker_schedule[rnd] if worker_schedule else None
            self.run_round(rnd, n)
            losses.append(self.eval_loss())
        return {"losses": losses, "rounds": self.log}
