"""Colmena-analog ensemble steering (paper §5.2, §5.6).

Thinker -> TaskServer -> workers, with the paper's library-level ProxyStore
integration: task inputs/results above a per-task-type threshold are
replaced by proxies before entering the task server queue
(``maybe_proxy``), exactly as Colmena registers a Store + threshold.

The TaskServer models the workflow-engine data path: every queued message is
serialized through the server with a configurable relay throughput, so bulky
values clog it (Fig 7/11's effect) while proxies do not.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core import Store, frame_nbytes, serialize
from repro.core.proxy import extract, is_proxy
from repro.core.store import maybe_proxy


@dataclass
class SteerConfig:
    n_workers: int = 2
    proxy_threshold: int | None = 100_000   # None -> proxies disabled
    server_bandwidth_bps: float = 50e6      # pickle-through-Redis regime
    server_latency_s: float = 0.002


class TaskServer:
    """In-process stand-in for the workflow engine's central data path."""

    def __init__(self, cfg: SteerConfig, store: Store | None) -> None:
        self.cfg = cfg
        self.store = store
        self.tasks: queue.Queue = queue.Queue()
        self.results: queue.Queue = queue.Queue()
        self.bytes_moved = 0
        self._lock = threading.Lock()

    def _relay(self, obj: Any) -> Any:
        """Everything passing the server pays serialization + bandwidth —
        twice (into and out of the engine process), as in the hub-spoke
        Parsl/Colmena data path the paper measures (§5.2)."""
        nbytes = frame_nbytes(serialize(obj))
        with self._lock:
            self.bytes_moved += nbytes
        time.sleep(self.cfg.server_latency_s
                   + 2 * nbytes / self.cfg.server_bandwidth_bps)
        return obj

    def submit(self, fn: Callable, arg: Any) -> None:
        if self.store is not None and self.cfg.proxy_threshold is not None:
            arg = maybe_proxy(self.store, arg, self.cfg.proxy_threshold)
        self.tasks.put((fn, self._relay(arg)))

    def put_result(self, value: Any) -> None:
        if self.store is not None and self.cfg.proxy_threshold is not None:
            value = maybe_proxy(self.store, value, self.cfg.proxy_threshold)
        self.results.put(self._relay(value))


def _worker_loop(server: TaskServer, stop: threading.Event) -> None:
    while not stop.is_set():
        try:
            fn, arg = server.tasks.get(timeout=0.1)
        except queue.Empty:
            continue
        if is_proxy(arg):
            arg = extract(arg)
        server.put_result(fn(arg))


class Steering:
    """Thinker loop: keep ``n_outstanding`` tasks in flight, consume results."""

    def __init__(self, cfg: SteerConfig, store: Store | None) -> None:
        self.cfg = cfg
        self.server = TaskServer(cfg, store)
        self._stop = threading.Event()
        self._threads = [
            threading.Thread(target=_worker_loop,
                             args=(self.server, self._stop), daemon=True)
            for _ in range(cfg.n_workers)
        ]
        for t in self._threads:
            t.start()

    def run(self, fn: Callable, make_input: Callable[[int], Any],
            n_tasks: int, n_outstanding: int = 4) -> dict:
        t0 = time.perf_counter()
        submitted = received = 0
        results = []
        while received < n_tasks:
            while submitted < n_tasks and \
                    submitted - received < n_outstanding:
                self.server.submit(fn, make_input(submitted))
                submitted += 1
            value = self.server.results.get()
            if is_proxy(value):
                value = extract(value)
            results.append(value)
            received += 1
        wall = time.perf_counter() - t0
        return {"wall_s": wall, "tasks_per_s": n_tasks / wall,
                "server_bytes": self.server.bytes_moved,
                "results": results}

    def close(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=1)
