"""Globus-Compute-analog FaaS executor (paper §2, §5.1).

Reproduces the properties that make the paper's baseline slow so the
benchmarks can measure what proxies remove:

* every task payload (pickled fn + args) and every result transits the
  "cloud" — modeled as latency + bandwidth on BOTH legs,
* a hard payload cap (Globus Compute enforces 5 MB) raises
  ``PayloadTooLarge``,
* workers are persistent processes on the "endpoint"; they can resolve
  proxies (import repro) like any consumer.

With ProxyStore, tasks carry ~300-byte proxies instead of data, so the cloud
hop cost collapses to the latency floor (Fig 5's effect).
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import queue as queue_mod
import threading
import time
import traceback
import uuid
from concurrent.futures import Future
from dataclasses import dataclass

_CTX = mp.get_context("spawn")


class PayloadTooLarge(RuntimeError):
    pass


@dataclass
class CloudModel:
    # Defaults calibrated to the paper's measured Globus Compute regime:
    # tens-of-ms cloud latency floor, ~20 MB/s effective relay throughput.
    latency_s: float = 0.02          # per hop (client->cloud->endpoint)
    bandwidth_bps: float = 20e6      # cloud relay throughput
    payload_cap: int = 5 << 20       # Globus Compute's 5 MB

    def hop(self, n_bytes: int) -> float:
        return 2 * self.latency_s + n_bytes / self.bandwidth_bps


def _worker_main(task_q, result_q) -> None:
    while True:
        item = task_q.get()
        if item is None:
            return
        task_id, blob = item
        try:
            fn, args, kwargs = pickle.loads(blob)
            result = fn(*args, **kwargs)
            payload = pickle.dumps(("ok", result), protocol=5)
        except Exception:  # noqa: BLE001
            payload = pickle.dumps(("err", traceback.format_exc()), protocol=5)
        result_q.put((task_id, payload))


class FaasExecutor:
    """submit(fn, *args) -> Future, with simulated cloud data path."""

    def __init__(self, n_workers: int = 2,
                 cloud: CloudModel | None = None) -> None:
        self.cloud = cloud or CloudModel()
        self._task_q = _CTX.Queue()
        self._result_q = _CTX.Queue()
        self._futures: dict[str, Future] = {}
        self._lock = threading.Lock()
        self._workers = [
            _CTX.Process(target=_worker_main,
                         args=(self._task_q, self._result_q), daemon=True)
            for _ in range(n_workers)
        ]
        for w in self._workers:
            w.start()
        self._collector = threading.Thread(target=self._collect, daemon=True)
        self._collector.start()

    def submit(self, fn, *args, **kwargs) -> Future:
        blob = pickle.dumps((fn, args, kwargs), protocol=5)
        if len(blob) > self.cloud.payload_cap:
            raise PayloadTooLarge(
                f"task payload {len(blob)}B exceeds cap "
                f"{self.cloud.payload_cap}B (pass a proxy instead)")
        time.sleep(self.cloud.hop(len(blob)))  # client -> cloud -> endpoint
        task_id = uuid.uuid4().hex
        fut: Future = Future()
        with self._lock:
            self._futures[task_id] = fut
        self._task_q.put((task_id, blob))
        return fut

    def _collect(self) -> None:
        # The simulated endpoint -> cloud -> client hop is paid OFF this
        # thread (one timer per result): N concurrent task results overlap
        # their transfers like real cloud legs do.  Sleeping the hop here
        # made N results pay *cumulative* latency, inflating the baseline
        # the proxy path is measured against.
        while True:
            try:
                task_id, payload = self._result_q.get(timeout=1.0)
            except queue_mod.Empty:
                continue
            with self._lock:
                fut = self._futures.pop(task_id, None)
            if fut is None:
                continue
            if len(payload) > self.cloud.payload_cap:
                fut.set_exception(PayloadTooLarge(
                    f"result {len(payload)}B exceeds cap"))
                continue
            timer = threading.Timer(self.cloud.hop(len(payload)),
                                    self._deliver, args=(fut, payload))
            timer.daemon = True
            timer.start()

    @staticmethod
    def _deliver(fut: Future, payload: bytes) -> None:
        try:
            status, value = pickle.loads(payload)
        except Exception as e:  # noqa: BLE001 - surface, don't kill timer
            fut.set_exception(e)
            return
        if status == "ok":
            fut.set_result(value)
        else:
            fut.set_exception(RuntimeError(value))

    def shutdown(self) -> None:
        for _ in self._workers:
            self._task_q.put(None)
        for w in self._workers:
            w.join(timeout=3)
            if w.is_alive():
                w.terminate()
