"""The pluggable broker protocol the stream plane runs over.

A :class:`Broker` owns topics: ordered sequences of events, each carrying
a small metadata map plus an opaque payload blob.  Named consumer groups
subscribe with independent cursors; an event is delivered to every group
whose filter matches its metadata, and its payload is retained until the
LAST subscribed group acks it — so the payload bytes cross the data plane
once regardless of fanout (the "proxy-on-publish" pattern: in the Store
layer the blob is a serialized proxy, and heavyweight data rides the
object store's fast path instead of the broker).

In-tree implementations: :class:`repro.stream.kv.KVBroker` (group state
in the owning KV server / PS-endpoint — works across processes and
sites) and :class:`repro.stream.local.LocalBroker` (in-process queues,
no server).  A Redis-shim broker can slot in behind the same ABC.

**Delivery guarantees.**  Within one broker incarnation delivery is
exactly-once per group (cursor + ack).  Across a failure (a KVBroker
over the sharded fabric with replication) delivery is **at-least-once**:
group cursors are replicated with the topic, so committed events are
never skipped, but events in flight at the crash are redelivered.
Consumers needing exactly-once must dedup by ``seq`` — an event's
sequence number is stable across failover (``StreamConsumer`` offers
``dedup=True`` for this).  Poison events stop recycling after
``max_deliveries`` (:meth:`Broker.set_limit`): the next
:meth:`Broker.requeue` moves them to ``<topic>.dlq``.
"""
from __future__ import annotations

import abc
from typing import Any, NamedTuple


class BrokerEvent(NamedTuple):
    """One delivered event.  ``data`` is None for metadata-only takes
    (``payload=False`` subscriptions — metrics taps), for events whose
    payload was reaped by a lease, and for the terminal end-of-stream
    marker (``end=True``)."""

    seq: int
    data: Any               # bytes-like | None
    meta: dict
    end: bool = False


class Broker(abc.ABC):
    """Pub/sub topics with consumer groups, filters, and backpressure.

    Implementations must be safe to drive from multiple threads (the
    stream plane overlaps producers and consumers by construction).
    """

    @abc.abstractmethod
    def publish(self, topic: str, data, *, meta: dict | None = None,
                ttl: float | None = None,
                timeout: float | None = None) -> int:
        """Append one event; returns its sequence number.  Parks (up to
        ``timeout``) when the topic has a backpressure limit and its
        unacked buffer is full; raises TimeoutError past the deadline and
        RuntimeError on a closed topic."""

    @abc.abstractmethod
    def subscribe(self, topic: str, group: str, *, start: str = "new",
                  filter: dict | None = None) -> dict:  # noqa: A002
        """Create consumer group ``group`` (idempotent).  ``start="begin"``
        queues retained events that pass ``filter`` (a
        :mod:`repro.stream.filters` spec); ``"new"`` starts from the next
        publish.  Returns ``{"created", "queued", "count", "closed"}``."""

    @abc.abstractmethod
    def unsubscribe(self, topic: str, group: str) -> None:
        """Drop the group, releasing its outstanding payload references."""

    @abc.abstractmethod
    def take(self, topic: str, group: str, *, timeout: float = 60.0,
             payload: bool = True) -> BrokerEvent:
        """Block until an event is deliverable to ``group``; the event
        stays unacked until :meth:`ack`.  Returns ``end=True`` once the
        topic is closed and drained; raises TimeoutError."""

    @abc.abstractmethod
    def take_batch(self, topic: str, group: str, n: int, *,
                   payload: bool = True) -> list[BrokerEvent]:
        """Non-blocking: up to ``n`` already-deliverable events."""

    @abc.abstractmethod
    def ack(self, topic: str, group: str, seqs) -> None:
        """Release the group's reference on delivered events (the payload
        is evicted after the last group acks).  Idempotent."""

    @abc.abstractmethod
    def requeue(self, topic: str, group: str, seqs,
                reason: str | None = None) -> None:
        """Hand delivered-but-unprocessed events back to the group (they
        redeliver in sequence order) — how a consumer returns prefetched
        events on close instead of leaking them.  An event already
        delivered ``max_deliveries`` times (:meth:`set_limit`) is NOT
        requeued: it moves to the ``<topic>.dlq`` dead-letter topic with
        a ``"dlq"`` metadata record carrying the origin topic/group/seq,
        the delivery count, and ``reason`` — poison events stop spinning
        and become observable via a ``payload=False`` tap on the DLQ."""

    @abc.abstractmethod
    def set_limit(self, topic: str, limit: int | None,
                  max_deliveries: int | None = None) -> None:
        """Bound the topic's unacked-event buffer (credit-based
        backpressure); falsy ``limit`` clears the bound.
        ``max_deliveries`` bounds deliveries per (group, event) before
        the event dead-letters on its next requeue (None leaves the
        current setting untouched; 0 clears it)."""

    @abc.abstractmethod
    def close_topic(self, topic: str) -> None:
        """Set the end-of-stream marker and release parked consumers."""

    @abc.abstractmethod
    def stat(self, topic: str) -> dict:
        """``{"count", "closed"}`` plus group/backpressure state."""

    def close(self) -> None:
        """Release broker-side client resources (default: nothing)."""
