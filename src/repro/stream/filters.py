"""Declarative event-metadata filters, evaluated server-side.

A filter is a plain (msgpack-serializable) dict so it can ride a
subscribe request to whichever process owns the topic — the KV server,
a PS-endpoint, or an in-process broker — and be evaluated there against
each event's metadata map.  Filtered-out events are acked for the group
without ever resolving the payload: zero bytes cross the data plane.

Spec grammar (``m`` is the event's metadata dict)::

    {"key": k}                                  m[k] exists (truthy test:
                                                op defaults to "exists")
    {"key": k, "op": "==", "value": v}          m[k] == v
    {"key": k, "op": "!=", "value": v}          m[k] != v   (missing: True)
    {"key": k, "op": ">" | ">=" | "<" | "<=", "value": v}
    {"key": k, "op": "in", "value": [v, ...]}   m[k] in value
    {"key": k, "op": "contains", "value": v}    v in m[k]
    {"all": [spec, ...]}                        conjunction
    {"any": [spec, ...]}                        disjunction
    {"not": spec}                               negation

A comparison on a missing key is False (except ``!=``), and any type
error during evaluation makes that clause False — a malformed event can
never take down the broker's delivery loop.
"""
from __future__ import annotations

from typing import Any, Callable

_MISSING = object()


def _compare(op: str, a: Any, b: Any) -> bool:
    if op == "==":
        return a == b
    if op == "!=":
        return a != b
    if op == ">":
        return a > b
    if op == ">=":
        return a >= b
    if op == "<":
        return a < b
    if op == "<=":
        return a <= b
    if op == "in":
        return a in b
    if op == "contains":
        return b in a
    raise ValueError(f"unknown filter op {op!r}")


def compile_filter(spec: dict) -> Callable[[dict], bool]:
    """Compile a filter spec into ``fn(meta) -> bool``.

    Raises ``ValueError`` on a malformed spec (at subscribe time — never
    during delivery)."""
    if not isinstance(spec, dict):
        raise ValueError(f"filter spec must be a dict, got {type(spec)}")
    if "all" in spec:
        fns = [compile_filter(s) for s in spec["all"]]
        return lambda m: all(fn(m) for fn in fns)
    if "any" in spec:
        fns = [compile_filter(s) for s in spec["any"]]
        return lambda m: any(fn(m) for fn in fns)
    if "not" in spec:
        fn = compile_filter(spec["not"])
        return lambda m: not fn(m)
    if "key" not in spec:
        raise ValueError(f"filter spec needs 'key'/'all'/'any'/'not': {spec}")
    key = spec["key"]
    op = spec.get("op", "exists")
    if op == "exists":
        return lambda m: key in m
    value = spec.get("value")
    if op not in ("==", "!=", ">", ">=", "<", "<=", "in", "contains"):
        raise ValueError(f"unknown filter op {op!r}")

    def fn(m: dict, key=key, op=op, value=value) -> bool:
        a = m.get(key, _MISSING)
        if a is _MISSING:
            return op == "!="
        try:
            return _compare(op, a, value)
        except TypeError:
            return False

    return fn
