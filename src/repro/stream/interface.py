"""StreamProducer / StreamConsumer — the user-facing stream plane.

A producer appends serialized objects to a topic on a pluggable
:class:`~repro.stream.broker.Broker`; any number of named consumer
groups iterate the topic independently, each seeing every event whose
filter matches (the broker retains a payload until the LAST group acks
it, so the bytes cross the data plane once regardless of fanout).

Consumers ack-on-delivery with piggybacked batching: delivered events
accumulate locally and flush in one ``ack`` exchange every
``ack_every`` items or right before the next blocking take — a fast
consumer pays one lifecycle round trip per batch, not per item.
Prefetched events stay UNACKED until actually delivered, which is what
makes :meth:`StreamConsumer.close` safe: anything prefetched but never
handed to the application is returned to the group (requeued in order)
instead of leaking its payload reference — a crashed-or-abandoning
consumer loses nothing for its group.

**Delivery contract.**  Over a replicated broker (the sharded fabric)
delivery is at-least-once across failover: committed events are never
skipped, but an event in flight at a crash is redelivered with the SAME
sequence number.  Consumers needing exactly-once semantics pass
``dedup=True`` — already-delivered seqs are acked and dropped instead
of yielded — or dedup by ``seq`` themselves.  Poison events (failing
handlers that requeue them repeatedly) dead-letter to ``<topic>.dlq``
after the producer's ``max_deliveries`` bound.
"""
from __future__ import annotations

from typing import Any, Callable, Iterator

from repro.stream.broker import Broker, BrokerEvent


class StreamProducer:
    """Append objects to a topic; close to mark end-of-stream.

    ``serializer`` turns objects into bytes-likes (default: payloads
    must already be bytes-like).  ``limit`` installs credit-based
    backpressure on the topic: appends park once ``limit`` events sit
    unacked, until consumer acks free slots (TimeoutError past
    ``timeout``).  ``max_deliveries`` bounds redeliveries per (group,
    event): an event requeued past it moves to ``<topic>.dlq`` instead
    of recycling forever.  Usable as a context manager — the topic
    closes on exit so consumer groups observe end-of-stream instead of
    timing out.
    """

    def __init__(self, broker: Broker, topic: str, *,
                 serializer: Callable[[Any], Any] | None = None,
                 ttl: float | None = None, limit: int | None = None,
                 max_deliveries: int | None = None,
                 timeout: float | None = None) -> None:
        self.broker = broker
        self.topic = topic
        self.ttl = ttl
        self.timeout = timeout
        self._serializer = serializer
        if limit or max_deliveries:
            broker.set_limit(topic, int(limit) if limit else None,
                             max_deliveries=max_deliveries)

    def append(self, obj: Any, *, meta: dict | None = None) -> int:
        """Serialize + publish one event; returns its sequence number.
        ``meta`` is the small metadata map consumer-group filters are
        evaluated against (it rides the broker, not the data plane)."""
        data = self._serializer(obj) if self._serializer else obj
        return self.broker.publish(self.topic, data, meta=meta,
                                   ttl=self.ttl, timeout=self.timeout)

    def close(self) -> None:
        self.broker.close_topic(self.topic)

    def stat(self) -> dict:
        return self.broker.stat(self.topic)

    def __enter__(self) -> "StreamProducer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class StreamConsumer:
    """One consumer group's iterator over a topic.

    ``__next__`` blocks (up to the mutable ``timeout``) for the next
    event, then batch-prefetches the already-deliverable tail in ONE
    exchange; iteration ends (StopIteration) once the topic is closed
    and the group drained.  ``payload=False`` subscribes a metadata-only
    tap: iteration yields the metadata dicts and the payload bytes are
    never resolved — combined with a server-side ``filter``, events the
    group does not want cost zero data-plane traffic.

    Events are acked only when DELIVERED to the application (flushed in
    batches of ``ack_every``); :meth:`close` flushes pending acks and
    requeues anything prefetched-but-undelivered back to the group, so
    abandoning mid-stream leaks no payload references.  Iterate inside a
    ``with`` block (or try/finally ``close()``).

    ``dedup=True`` upgrades the at-least-once redelivery that follows a
    broker failover to exactly-once *for this consumer*: an event whose
    seq was already delivered is acked (releasing its reference) and
    silently skipped instead of yielded.  Seen seqs are tracked in
    memory for the consumer's lifetime.
    """

    def __init__(self, broker: Broker, topic: str, group: str = "default",
                 *, start: str = "new", filter: dict | None = None,  # noqa: A002
                 payload: bool = True, prefetch: int = 8,
                 timeout: float = 60.0, ack_every: int = 8,
                 dedup: bool = False,
                 deserializer: Callable[[Any], Any] | None = None) -> None:
        self.broker = broker
        self.topic = topic
        self.group = group
        self.payload = payload
        self.prefetch = max(0, int(prefetch))
        self.timeout = timeout
        self.ack_every = max(1, int(ack_every))
        self.dedup = bool(dedup)
        self._deserializer = deserializer
        self._buffer: list[BrokerEvent] = []   # taken (unacked), undelivered
        self._to_ack: list[int] = []           # delivered, ack not yet sent
        self._seen: set[int] = set()           # dedup=True: delivered seqs
        self._closed = False
        self._ended = False
        broker.subscribe(topic, group, start=start, filter=filter)

    # -- lifecycle -----------------------------------------------------------
    def pending(self) -> int:
        """Prefetched events not yet delivered.  Unlike the pre-broker
        stream plane these are still UNACKED — ``close()`` returns them
        to the group rather than losing them."""
        return len(self._buffer)

    def _flush_acks(self) -> None:
        if self._to_ack:
            seqs, self._to_ack = self._to_ack, []
            self.broker.ack(self.topic, self.group, seqs)

    def close(self, *, unsubscribe: bool = False) -> None:
        """Flush delivered-event acks and hand every prefetched-but-
        undelivered event back to the group (redelivered, in order, to
        the group's next taker).  ``unsubscribe=True`` additionally
        drops the group, releasing all its outstanding references."""
        if self._closed:
            return
        self._closed = True
        buf, self._buffer = self._buffer, []
        try:
            self._flush_acks()
            if buf:
                self.broker.requeue(self.topic, self.group,
                                    [ev.seq for ev in buf])
        finally:
            if unsubscribe:
                self.broker.unsubscribe(self.topic, self.group)

    def __enter__(self) -> "StreamConsumer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- iteration -----------------------------------------------------------
    def _deliver(self, ev: BrokerEvent) -> Any:
        self._to_ack.append(ev.seq)
        if len(self._to_ack) >= self.ack_every:
            self._flush_acks()
        if not self.payload:
            return ev.meta
        if ev.data is None:
            raise LookupError(
                f"stream {self.topic!r} event {ev.seq} payload is gone "
                f"(lease-reaped or evicted)")
        return self._deserializer(ev.data) if self._deserializer else ev.data

    def take_event(self) -> BrokerEvent:
        """One raw event (blocking), payload deserialized, ack deferred
        like ``__next__`` — for consumers that want seq + meta + data."""
        ev = self._take()
        if ev.end:
            raise StopIteration
        obj = self._deliver(ev)
        return BrokerEvent(ev.seq, obj if self.payload else ev.data,
                           ev.meta)

    def _take(self) -> BrokerEvent:
        while True:
            ev = self._take_once()
            if ev.end or not self.dedup:
                return ev
            if ev.seq in self._seen:
                # failover redelivery: ack to release the reference,
                # skip the yield — the dedup-by-seq contract
                self._to_ack.append(ev.seq)
                continue
            self._seen.add(ev.seq)
            return ev

    def _take_once(self) -> BrokerEvent:
        if self._closed:
            raise RuntimeError(
                f"consumer of stream {self.topic!r} is closed")
        if self._buffer:
            return self._buffer.pop(0)
        if self._ended:
            return BrokerEvent(-1, None, {}, end=True)
        self._flush_acks()   # piggyback before parking: frees credits
        ev = self.broker.take(self.topic, self.group,
                              timeout=self.timeout, payload=self.payload)
        if ev.end:
            self._ended = True
            return ev
        if self.prefetch:
            self._buffer.extend(self.broker.take_batch(
                self.topic, self.group, self.prefetch,
                payload=self.payload))
        return ev

    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self) -> Any:
        ev = self._take()
        if ev.end:
            self._flush_acks()
            raise StopIteration
        return self._deliver(ev)
