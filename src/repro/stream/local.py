"""LocalBroker: the in-process broker — queues and a condition variable,
no server.

For tests and single-node pipelines where producer and consumers share
one process.  Implements the full broker semantics (groups, filters,
per-group acks with evict-after-last-ack, backpressure) so code written
against :class:`repro.stream.broker.Broker` moves to the KV broker — or a
future Redis shim — without changes.
"""
from __future__ import annotations

import collections
import threading
import time

from repro.stream.broker import Broker, BrokerEvent
from repro.stream.filters import compile_filter


def _as_bytes(data) -> bytes:
    """Flatten bytes-likes and multi-segment frames to one owned blob
    (the broker retains it across the producer's next reuse of buffers)."""
    if isinstance(data, (bytes, bytearray, memoryview)):
        return bytes(data)
    from repro.core.serialize import as_segments

    return b"".join(bytes(memoryview(s)) for s in as_segments(data))


class _Topic:
    __slots__ = ("count", "closed", "data", "meta", "owners", "groups",
                 "limit", "max_deliveries", "deliveries")

    def __init__(self) -> None:
        self.count = 0
        self.closed = False
        self.data: dict[int, bytes] = {}       # payloads, evicted on last ack
        self.meta: dict[int, dict] = {}
        self.owners: dict[int, int] = {}       # seq -> outstanding group refs
        self.groups: dict[str, dict] = {}      # {queue, unacked, fn, filter}
        self.limit: int | None = None
        self.max_deliveries: int | None = None
        self.deliveries: dict[tuple[str, int], int] = {}


class LocalBroker(Broker):
    def __init__(self) -> None:
        self._topics: dict[str, _Topic] = {}
        self._cond = threading.Condition()

    def _topic(self, topic: str) -> _Topic:
        return self._topics.setdefault(topic, _Topic())

    # -- producer side -------------------------------------------------------
    def publish(self, topic: str, data, *, meta: dict | None = None,
                ttl: float | None = None,
                timeout: float | None = None) -> int:
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else 60.0)
        with self._cond:
            t = self._topic(topic)
            while (t.limit is not None and len(t.owners) >= t.limit
                   and not t.closed):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"stream {topic!r} publish timed out on "
                        f"backpressure (buffer full)")
                self._cond.wait(remaining)
            if t.closed:
                raise RuntimeError(f"stream {topic!r} is closed")
            seq = t.count
            t.count += 1
            if meta:
                t.meta[seq] = dict(meta)
            m = meta or {}
            matched = [g for g in t.groups.values()
                       if g["fn"] is None or g["fn"](m)]
            if t.groups and not matched:
                pass           # filtered out by every group: never stored
            else:
                t.data[seq] = _as_bytes(data)
                if matched:
                    t.owners[seq] = len(matched)
            for g in matched:
                g["queue"].append(seq)
            self._cond.notify_all()
            return seq

    # -- group lifecycle -----------------------------------------------------
    def subscribe(self, topic: str, group: str, *, start: str = "new",
                  filter: dict | None = None) -> dict:  # noqa: A002
        with self._cond:
            t = self._topic(topic)
            g = t.groups.get(group)
            created = g is None
            if created:
                fn = compile_filter(filter) if filter else None
                g = {"queue": collections.deque(), "unacked": set(),
                     "fn": fn, "filter": filter}
                t.groups[group] = g
                if start == "begin":
                    for seq in range(t.count):
                        if seq not in t.data:
                            continue
                        if fn is not None and not fn(t.meta.get(seq) or {}):
                            continue
                        g["queue"].append(seq)
                        t.owners[seq] = t.owners.get(seq, 0) + 1
                self._cond.notify_all()
            return {"created": created, "queued": len(g["queue"]),
                    "count": t.count, "closed": t.closed}

    def unsubscribe(self, topic: str, group: str) -> None:
        with self._cond:
            t = self._topic(topic)
            g = t.groups.pop(group, None)
            if g is None:
                return
            for seq in (*g["queue"], *g["unacked"]):
                self._drop_owner(t, seq)
            for k in [k for k in t.deliveries if k[0] == group]:
                t.deliveries.pop(k, None)
            self._cond.notify_all()

    def _drop_owner(self, t: _Topic, seq: int) -> None:
        n = t.owners.get(seq)
        if n is None:
            return
        if n <= 1:
            del t.owners[seq]
            t.data.pop(seq, None)       # last group acked: evict
            t.meta.pop(seq, None)
        else:
            t.owners[seq] = n - 1

    # -- consumer side -------------------------------------------------------
    def _pop(self, t: _Topic, group: str, payload: bool):
        g = t.groups.get(group)
        if g is None or not g["queue"]:
            return None
        seq = g["queue"].popleft()
        g["unacked"].add(seq)
        t.deliveries[(group, seq)] = t.deliveries.get((group, seq), 0) + 1
        return BrokerEvent(seq, t.data.get(seq) if payload else None,
                           t.meta.get(seq) or {})

    def take(self, topic: str, group: str, *, timeout: float = 60.0,
             payload: bool = True) -> BrokerEvent:
        deadline = time.monotonic() + float(timeout)
        with self._cond:
            t = self._topic(topic)
            while True:
                ev = self._pop(t, group, payload)
                if ev is not None:
                    return ev
                if t.closed:
                    return BrokerEvent(-1, None, {}, end=True)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"stream {topic!r} group {group!r} timed out")
                self._cond.wait(remaining)

    def take_batch(self, topic: str, group: str, n: int, *,
                   payload: bool = True) -> list[BrokerEvent]:
        out: list[BrokerEvent] = []
        with self._cond:
            t = self._topic(topic)
            while len(out) < n:
                ev = self._pop(t, group, payload)
                if ev is None:
                    break
                out.append(ev)
        return out

    def ack(self, topic: str, group: str, seqs) -> None:
        with self._cond:
            t = self._topic(topic)
            g = t.groups.get(group)
            if g is None:
                return
            acked = {int(s) for s in seqs} & g["unacked"]
            g["unacked"] -= acked
            for seq in acked:
                t.deliveries.pop((group, seq), None)
                self._drop_owner(t, seq)
            if acked:
                self._cond.notify_all()   # acks free backpressure credits

    def requeue(self, topic: str, group: str, seqs,
                reason: str | None = None) -> None:
        with self._cond:
            t = self._topic(topic)
            g = t.groups.get(group)
            if g is None:
                return
            claimed = {int(s) for s in seqs} & g["unacked"]
            if not claimed:
                return
            limit = t.max_deliveries
            dead = ({s for s in claimed
                     if t.deliveries.get((group, s), 0) >= limit}
                    if limit else set())
            back = claimed - dead
            g["unacked"] -= claimed
            if back:
                g["queue"] = collections.deque(
                    sorted(back | set(g["queue"])))
            for seq in sorted(dead):
                self._dead_letter(t, topic, group, seq, reason)
            self._cond.notify_all()

    def _dead_letter(self, t: _Topic, topic: str, group: str, seq: int,
                     reason: str | None) -> None:
        """Move a poison event to ``<topic>.dlq``: same payload bytes,
        original metadata plus a ``"dlq"`` failure record, then release
        the group's claim on the original."""
        from repro.core.kv_tcp import dlq_topic

        deliveries = t.deliveries.pop((group, seq), 0)
        d = self._topic(dlq_topic(topic))
        if not d.closed:
            dseq = d.count
            d.count += 1
            meta = dict(t.meta.get(seq) or {})
            meta["dlq"] = {"topic": topic, "group": group, "seq": seq,
                           "deliveries": deliveries, "reason": reason}
            d.meta[dseq] = meta
            matched = [g2 for g2 in d.groups.values()
                       if g2["fn"] is None or g2["fn"](meta)]
            data = t.data.get(seq)
            if data is not None and not (d.groups and not matched):
                d.data[dseq] = data
                if matched:
                    d.owners[dseq] = len(matched)
            for g2 in matched:
                g2["queue"].append(dseq)
        self._drop_owner(t, seq)

    # -- topic admin ---------------------------------------------------------
    def set_limit(self, topic: str, limit: int | None,
                  max_deliveries: int | None = None) -> None:
        with self._cond:
            t = self._topic(topic)
            t.limit = int(limit) if limit else None
            if max_deliveries is not None:
                t.max_deliveries = (int(max_deliveries)
                                    if max_deliveries else None)
            self._cond.notify_all()

    def close_topic(self, topic: str) -> None:
        with self._cond:
            self._topic(topic).closed = True
            self._cond.notify_all()

    def stat(self, topic: str) -> dict:
        with self._cond:
            t = self._topic(topic)
            st: dict = {"count": t.count, "closed": t.closed}
            if t.groups:
                st["groups"] = {name: {"queued": len(g["queue"]),
                                       "unacked": len(g["unacked"])}
                                for name, g in t.groups.items()}
                st["buffered"] = len(t.owners)
                if t.limit is not None:
                    st["limit"] = t.limit
                if t.max_deliveries:
                    st["max_deliveries"] = t.max_deliveries
            return st
