"""Broker-backed pub/sub stream plane (arXiv:2407.01764 pattern three).

``StreamProducer``/``StreamConsumer`` over a pluggable :class:`Broker`
protocol: event *metadata* rides the broker, the payload rides the fast
data plane once regardless of fanout ("proxy-on-publish").  Named consumer
groups get independent cursors and per-group acks; server-side filters
skip the payload path entirely for filtered-out events; credit-based
backpressure parks producers when a topic's unacked buffer fills.

In-tree brokers:

* :class:`repro.stream.kv.KVBroker` — the KV stream table (any
  server-backed connector: kvserver / socket / endpoint / fabric), group
  state held in the owning server's :class:`repro.core.kv_tcp.StreamTable`.
* :class:`repro.stream.local.LocalBroker` — in-process queues, no server;
  for tests and single-node pipelines.

Submodules are imported lazily so :mod:`repro.core` modules can import
:mod:`repro.stream.filters` without a cycle.
"""
from __future__ import annotations

_LAZY = {
    "Broker": "repro.stream.broker",
    "BrokerEvent": "repro.stream.broker",
    "compile_filter": "repro.stream.filters",
    "LocalBroker": "repro.stream.local",
    "KVBroker": "repro.stream.kv",
    "StreamProducer": "repro.stream.interface",
    "StreamConsumer": "repro.stream.interface",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(target), name)
