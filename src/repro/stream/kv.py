"""KVBroker: the in-tree broker — group state lives in whichever server
owns the topic.

A thin adapter from the :class:`repro.stream.broker.Broker` protocol onto
a connector's ``stream_*`` ops, so the broker works over every
server-backed channel: a standalone KV server, per-node socket servers
(``location`` selects the producing node), peered PS-endpoints
(``location`` is the producer's endpoint UUID; subscriptions and takes
are peer-forwarded), and the sharded fabric (topics hash to their
primary shard and subscriptions fail over).

The payload lifecycle is exactly the proxy-on-publish story: the blob is
stored ONCE in the owning server's data plane with one reference per
matching consumer group, each delivery serves the bytes straight from
the data map, and the last group's ack evicts it.
"""
from __future__ import annotations

from repro.stream.broker import Broker, BrokerEvent


class KVBroker(Broker):
    def __init__(self, connector, location: str | None = None) -> None:
        if location is not None and \
                not getattr(connector, "supports_location", False):
            raise ValueError(
                f"{type(connector).__name__} does not support location "
                f"addressing: topics live on this channel's own server, "
                f"so a location={location!r} subscription would hang on a "
                f"topic that will never produce.  Use a socket or "
                f"endpoint connector (or drop location).")
        self.connector = connector
        self.location = location

    # -- producer side -------------------------------------------------------
    def publish(self, topic: str, data, *, meta: dict | None = None,
                ttl: float | None = None,
                timeout: float | None = None) -> int:
        return self.connector.stream_append(topic, data, ttl, meta=meta,
                                            timeout=timeout)

    # -- group lifecycle -----------------------------------------------------
    def subscribe(self, topic: str, group: str, *, start: str = "new",
                  filter: dict | None = None) -> dict:  # noqa: A002
        return self.connector.stream_subscribe(
            topic, group, start=start, filter=filter,
            location=self.location)

    def unsubscribe(self, topic: str, group: str) -> None:
        self.connector.stream_unsubscribe(topic, group,
                                          location=self.location)

    # -- consumer side -------------------------------------------------------
    def take(self, topic: str, group: str, *, timeout: float = 60.0,
             payload: bool = True) -> BrokerEvent:
        return self.connector.stream_take(topic, group, timeout=timeout,
                                          payload=payload,
                                          location=self.location)

    def take_batch(self, topic: str, group: str, n: int, *,
                   payload: bool = True) -> list[BrokerEvent]:
        return self.connector.stream_take_batch(topic, group, n,
                                                payload=payload,
                                                location=self.location)

    def ack(self, topic: str, group: str, seqs) -> None:
        self.connector.stream_ack(topic, group, seqs,
                                  location=self.location)

    def requeue(self, topic: str, group: str, seqs,
                reason: str | None = None) -> None:
        self.connector.stream_requeue(topic, group, seqs, reason=reason,
                                      location=self.location)

    # -- topic admin ---------------------------------------------------------
    def set_limit(self, topic: str, limit: int | None,
                  max_deliveries: int | None = None) -> None:
        self.connector.stream_limit(topic, limit,
                                    max_deliveries=max_deliveries,
                                    location=self.location)

    def close_topic(self, topic: str) -> None:
        self.connector.stream_close(topic, location=self.location)

    def stat(self, topic: str) -> dict:
        return self.connector.stream_stat(topic, location=self.location)
