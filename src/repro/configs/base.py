"""Architecture + run configuration.

One ``ArchConfig`` instance per assigned architecture (see sibling modules).
``reduced()`` returns a same-family miniature for CPU smoke tests; the full
configs are only ever lowered abstractly (ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "silu"                # silu (SwiGLU) | gelu

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"         # einsum (GShard baseline) | scatter

    # --- attention variants ---
    sliding_window: int = 0          # 0 -> full causal

    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2-style shared attention) ---
    attn_every: int = 0              # shared attn block applied every k layers

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_frames: int = 1500           # precomputed frame embeddings (stub frontend)

    # --- VLM ---
    n_img_tokens: int = 0            # prefix positions carrying patch embeddings

    # --- numerics / structure knobs (perf-relevant; see EXPERIMENTS §Perf) ---
    pad_vocab_to: int = 256   # embedding rows padded so 'model' axis divides
    dtype: str = "bfloat16"
    scan_layers: bool = True
    scan_group: int = 0              # >1: sqrt-remat over layer groups
    remat: str = "full"              # full | none
    attn_chunk: int = 1024           # query-chunked reference attention
    attn_unroll: bool = False        # python-loop the chunk scan (cost variant)
    loss_chunk: int = 512            # sequence-chunked softmax-xent
    loss_unroll: bool = False
    attention_impl: str = "chunked"  # chunked | full | pallas

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab rounded up so TP axes divide evenly.

        Indivisible vocabs (whisper 51865, mamba2 50280, internvl2 92553)
        otherwise force the logits/loss compute to replicate across the
        'model' axis — measured as a >10x per-device FLOP blowup in the
        dry-run (EXPERIMENTS.md §Perf).  Standard practice (MaxText et al.).
        """
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m if m else self.vocab

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter count (used for 6ND roofline terms) -------------
    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params) — active differs for MoE."""
        D, FF, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        HD = self.hd

        def attn_params() -> int:
            p = D * self.n_heads * HD + 2 * D * self.n_kv_heads * HD \
                + self.n_heads * HD * D
            if self.qkv_bias:
                p += (self.n_heads + 2 * self.n_kv_heads) * HD
            return p

        def mlp_params(ff: int) -> int:
            return 3 * D * ff if self.act == "silu" else 2 * D * ff

        def ssm_params() -> int:
            di, st, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            in_proj = D * (2 * di + 2 * st + nh)
            conv = (di + 2 * st) * self.ssm_conv
            out = di * D
            extra = 2 * nh + nh + di  # A_log, dt_bias, D_skip, gating norm
            return in_proj + conv + out + extra

        emb = V * D * (1 if self.tie_embeddings else 2)
        total = active = emb

        if self.family in ("dense", "vlm"):
            per = attn_params() + mlp_params(FF) + 2 * D
            total += L * per
            active = total
        elif self.family == "moe":
            moe_ff = self.moe_d_ff or FF
            per_tot = attn_params() + self.n_experts * mlp_params(moe_ff) \
                + D * self.n_experts + 2 * D
            per_act = attn_params() + self.top_k * mlp_params(moe_ff) \
                + D * self.n_experts + 2 * D
            total += L * per_tot
            active += L * per_act
        elif self.family == "audio":
            dec = attn_params() * 2 + mlp_params(FF) + 3 * D  # self+cross
            enc = attn_params() + mlp_params(FF) + 2 * D
            total += L * dec + self.n_enc_layers * enc
            active = total
        elif self.family == "ssm":
            total += L * (ssm_params() + D)
            active = total
        elif self.family == "hybrid":
            shared = attn_params() + mlp_params(FF) + 2 * D
            total += L * (ssm_params() + D) + shared
            active = total
        else:
            raise ValueError(self.family)
        return total, active

    def reduced(self) -> "ArchConfig":
        """Same-family miniature for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 5),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            attn_chunk=64,
            loss_chunk=64,
        )
        if self.family == "moe":
            kw.update(n_experts=4, top_k=2, moe_d_ff=64)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.family == "hybrid":
            kw.update(attn_every=2, n_heads=4, n_kv_heads=4, head_dim=32)
        if self.family == "audio":
            kw.update(n_enc_layers=2, enc_frames=8)
        if self.family == "vlm":
            kw.update(n_img_tokens=4)
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input-shape grid (assigned): every cell is (arch x one of these)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell runs (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        if arch.family in ("ssm", "hybrid"):
            return True, "sub-quadratic (SSM state)"
        if arch.sliding_window:
            return True, "sub-quadratic (sliding-window KV)"
        return False, "skipped: pure full attention at 512k ctx"
    return True, ""
