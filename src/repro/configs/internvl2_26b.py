"""InternVL2-26B — InternViT frontend (STUB) + InternLM2-20B-style backbone.
[arXiv:2404.16821; hf]

The modality frontend is a stub per the assignment: ``input_specs()``
provides precomputed patch embeddings occupying the first ``n_img_tokens``
sequence positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, head_dim=128, n_img_tokens=256,
)
