"""Zamba2-1.2B — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

kv=32 with 32 heads => full MHA in the shared block (head_dim 64).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6,
)
