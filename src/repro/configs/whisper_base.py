"""Whisper-base — encoder-decoder; conv frontend is a STUB (precomputed
frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, head_dim=64, n_enc_layers=6, enc_frames=1500, act="gelu",
)
