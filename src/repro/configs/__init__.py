"""Assigned-architecture registry: ``get_arch(name)`` / ``--arch <id>``."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs.qwen2_5_14b import CONFIG as qwen2_5_14b
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.qwen2_5_32b import CONFIG as qwen2_5_32b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.internvl2_26b import CONFIG as internvl2_26b
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.mamba2_2_7b import CONFIG as mamba2_2_7b
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        qwen2_5_14b, phi4_mini_3_8b, llama3_405b, qwen2_5_32b,
        qwen3_moe_30b_a3b, mixtral_8x7b, internvl2_26b, whisper_base,
        mamba2_2_7b, zamba2_1_2b,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ARCHS", "get_arch"]
