"""Proxy-native serving engine: continuous batching over the proxy data plane.

Every tensor that crosses a process boundary rides the proxy substrate
PRs 1-5 built:

* **weights** — loaded from a proxy (``weights=``: an engine's published
  :meth:`ServeEngine.publish_weights` ``OwnedProxy`` whose ``borrow()``
  each worker process resolves to zero-copy views of ONE shm arena
  mapping) or lazily from a proxy-checkpoint manifest (``ckpts=``; the
  restore is ONE batched ``get_batch`` per store);
* **KV cache** — the grow-by-``jnp.concatenate`` static cache is replaced
  by paged storage: each request's KV lives in fixed-size
  :class:`~repro.models.serve_paths.KVBlockPool` blocks backed by
  refcounted arena slots with TTL leases (completion releases them;
  crashed owners are reclaimed by lease expiry under memory pressure);
* **scheduling** — a continuous-batching loop with per-request admission
  and completion: rows join as slots free up (each prefilled alone at its
  natural length, positions per row) and retire at their own
  ``max_new_tokens`` — no padded lockstep, no wasted decode steps.
  :meth:`ServeEngine.serve_stream` feeds the loop from a ``ProxyStream``
  (requests arrive as proxies; responses publish as ephemeral
  ``evict=True`` proxies through a result stream).

Families without a left-aligned attention cache (ssm / audio / hybrid)
and sliding-window configs keep a lockstep static batcher
(:meth:`ServeEngine._generate_static`) behind the same ``generate`` API.
"""
from __future__ import annotations

import time
import uuid
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.proxy import extract, get_factory, is_proxy
from repro.models.model import build_model
from repro.models.serve_paths import KVBlockPool, KVPoolExhausted
from repro.train.checkpoints import ProxyCheckpointManager

_EXHAUSTED = object()     # source sentinel: no request will ever come again


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0   # 0 -> greedy
    req_id: str = ""           # assigned at submission when empty


@dataclass
class Completion:
    req_id: str
    tokens: list[int]
    prompt_len: int
    queued_s: float            # submission -> admission
    total_s: float             # submission -> completion


@dataclass
class _Active:
    """One admitted request's scheduler state."""

    req: Request
    row: int
    length: int                # tokens in the dense row (prompt + generated)
    flushed: int               # tokens already paged out to KV blocks
    submit_t: float
    admit_t: float
    out: list[int] = field(default_factory=list)
    blocks: list = field(default_factory=list)


class _ListSource:
    """Source over a known request list (the ``generate`` compat path)."""

    def __init__(self, reqs: list[Request]) -> None:
        self._q = deque(reqs)

    def poll(self, block: bool):
        return self._q.popleft() if self._q else _EXHAUSTED

    def push_back(self, req: Request) -> None:
        self._q.appendleft(req)


class _StreamSource:
    """Source over a ProxyStream consumer: requests arrive as stream items
    (optionally proxies — resolved here), end with the producer's close.
    Polling is non-blocking while rows are busy (a short channel wait) and
    blocking when the engine is idle."""

    _POLL_S = 0.002

    def __init__(self, stream, *, timeout: float,
                 consume: bool = True) -> None:
        self._stream = stream
        self._timeout = timeout
        self._consume = consume
        self._pending: deque = deque()
        self._done = False

    def poll(self, block: bool):
        if self._pending:
            return self._pending.popleft()
        if self._done:
            return _EXHAUSTED
        self._stream.timeout = self._timeout if block else self._POLL_S
        try:
            item = next(self._stream)
        except StopIteration:
            self._done = True
            return _EXHAUSTED
        except TimeoutError:
            if block:
                self._done = True      # idle past the deadline: give up
                return _EXHAUSTED
            return None
        return _as_request(item, consume=self._consume)

    def push_back(self, req: Request) -> None:
        self._pending.appendleft(req)


def _as_request(item, consume: bool = False) -> Request:
    factory = get_factory(item) if is_proxy(item) else None
    if factory is not None:
        item = extract(item)
    if isinstance(item, dict):
        req = Request(prompt=list(item["prompt"]),
                      max_new_tokens=int(item.get("max_new_tokens", 16)),
                      temperature=float(item.get("temperature", 0.0)),
                      req_id=str(item.get("req_id", "")))
    elif isinstance(item, Request):
        req = item
    else:
        raise TypeError(
            f"cannot interpret stream item as a request: {type(item)}")
    if consume and factory is not None \
            and not getattr(factory, "evict", True) \
            and not getattr(factory, "owned", True):
        # the engine has copied what it needs: free the request's slot now
        # instead of waiting out its lease (keeps the arena's working set
        # at the in-flight batch, not the whole request history)
        try:
            factory._store().evict(factory.key)
        except Exception:  # noqa: BLE001 - reclamation is best-effort
            pass
    return req


@partial(jax.jit, static_argnames=("vocab",))
def _sample_tokens(logits, temps, key, *, vocab: int):
    """Per-row sampling: each row uses ITS OWN temperature (greedy where
    temperature == 0) — one batched categorical, not ``temps[0]`` for all."""
    lv = logits[:, :vocab].astype(jnp.float32)
    greedy = jnp.argmax(lv, axis=-1)
    scaled = lv / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 ckpts: ProxyCheckpointManager | None = None,
                 weights=None, kv_store=None,
                 max_batch: int = 8, max_context: int = 256,
                 block_tokens: int = 16,
                 kv_budget_bytes: int | None = None,
                 lease_ttl: float | None = 60.0,
                 seed: int = 1234) -> None:
        self.cfg = cfg
        self.model = build_model(cfg)
        if params is None:
            if weights is not None:
                # worker path: a borrowed weight proxy resolves to zero-copy
                # views of the publisher's arena mapping; jnp.asarray is the
                # single host->device upload per worker
                params = jax.tree.map(jnp.asarray, extract(weights))
            elif ckpts is not None:   # lazy proxy restore (batched get)
                state = ckpts.restore()
                params = jax.tree.map(jnp.asarray, state["params"])
            else:
                params = self.model.init(jax.random.key(0))
        self.params = params
        self.max_batch = int(max_batch)
        self.max_context = int(max_context)
        self.block_tokens = int(block_tokens)
        self.lease_ttl = lease_ttl
        self._kv_budget = kv_budget_bytes
        self._kv_store = kv_store
        self._own_kv_store = False
        self._kv_pool: KVBlockPool | None = None
        self._weights_owned = None
        self._key = jax.random.key(seed)
        # continuous batching needs a left-aligned dense attention cache;
        # ring (sliding-window) and state-cache families stay lockstep
        self._continuous = (cfg.family in ("dense", "moe", "vlm")
                            and not cfg.sliding_window)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._scatter = jax.jit(self._scatter_rows, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # weight plane
    # ------------------------------------------------------------------
    def publish_weights(self, store, *, ttl: float | None = None):
        """Broadcast this engine's parameters once through ``store`` as ONE
        PSJ2 frame (on an shm store: one arena slot every consumer maps
        zero-copy).  Returns an :class:`~repro.core.OwnedProxy` the engine
        holds; hand each worker process a pickled ``borrow()`` of it —
        borrows pin the owner, carry no reference of their own, and resolve
        without the deep-copy an owned resolve pays."""
        host = jax.tree.map(np.asarray, self.params)
        self._weights_owned = store.owned_proxy(host, ttl=ttl)
        return self._weights_owned

    # ------------------------------------------------------------------
    # KV plane
    # ------------------------------------------------------------------
    def kv_pool(self) -> KVBlockPool:
        """The paged KV-cache pool (created lazily; a private shm-arena
        store when none was injected)."""
        if self._kv_pool is None:
            if self._kv_store is None:
                import tempfile

                from repro.core import Store
                from repro.core.connectors import SharedMemoryConnector

                self._kv_store = Store(
                    f"serve-kv-{uuid.uuid4().hex[:8]}",
                    SharedMemoryConnector(
                        tempfile.mkdtemp(prefix="repro-kv-")))
                self._own_kv_store = True
            budget = self._kv_budget
            pool = KVBlockPool(self._kv_store, self.cfg,
                               block_tokens=self.block_tokens,
                               budget_bytes=None,
                               lease_ttl=self.lease_ttl)
            if budget is None:
                # default: 2x the dense working set, so completed requests'
                # pages linger long enough for stats/debug without growing
                per_tok = 2 * self.cfg.n_layers * self.cfg.n_kv_heads \
                    * self.cfg.hd * pool.dtype.itemsize
                budget = 2 * self.max_batch * self.max_context * per_tok
            pool.budget_bytes = budget
            self._kv_pool = pool
        return self._kv_pool

    # ------------------------------------------------------------------
    # continuous scheduler
    # ------------------------------------------------------------------
    def _alloc_cache(self):
        cfg = self.cfg
        shape = (cfg.n_layers, self.max_batch, self.max_context,
                 cfg.n_kv_heads, cfg.hd)
        dt = jnp.dtype(cfg.dtype)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    @staticmethod
    def _scatter_rows(cache, kk, vv, perm, mask):
        """Write admitted rows' prefill KV into their cache rows in one
        fixed-shape update (`perm` maps cache row -> prefill row, `mask`
        selects admitted rows), so the trace count is one per prompt
        length — independent of how many rows each group admits."""
        s = cache["k"].shape[2]
        plen = kk.shape[2]
        pad = [(0, 0), (0, 0), (0, s - plen), (0, 0), (0, 0)]
        m = (mask[:, None] & (jnp.arange(s) < plen)[None, :]
             )[None, :, :, None, None]
        return {"k": jnp.where(m, jnp.pad(kk[:, perm], pad), cache["k"]),
                "v": jnp.where(m, jnp.pad(vv[:, perm], pad), cache["v"])}

    def _admit_group(self, group: list[tuple[Request, float]],
                     rows: list[int], state: dict,
                     ) -> tuple[list[_Active], list[Request]]:
        """Admit a same-prompt-length group with ONE batched prefill
        (padded to ``max_batch`` rows so the trace count is bounded by the
        number of distinct prompt lengths, not group sizes), page each
        request's KV into pool blocks, scatter the batch into its target
        rows in one cache update.  Returns (admitted, deferred) — requests
        the pool could not hold pages for come back deferred instead of
        failing the whole group."""
        cfg = self.cfg
        pool = self.kv_pool()
        plen = len(group[0][0].prompt)
        n = len(group)
        B = self.max_batch
        t0 = time.perf_counter()
        toks = np.zeros((B, plen), np.int32)
        for i, (req, _) in enumerate(group):
            toks[i] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["vision_emb"] = jnp.zeros(
                (B, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        logits, kv = self._prefill(self.params, batch)
        admit_t = time.perf_counter()
        self._key, sub = jax.random.split(self._key)
        temps = np.zeros(B, np.float32)
        temps[:n] = [req.temperature for req, _ in group]
        first = np.asarray(self._sample(logits, temps, sub))
        kh_all = np.asarray(kv["k"])            # (L, B, plen, KV, HD)
        vh_all = np.asarray(kv["v"])

        admitted: list[_Active] = []
        deferred: list[Request] = []
        for i, (req, submit_t) in enumerate(group):
            if deferred:
                deferred.append(req)
                continue
            try:
                blocks = pool.put_prefill(kh_all[:, i], vh_all[:, i])
            except KVPoolExhausted:
                deferred.append(req)
                continue
            a = _Active(req=req, row=rows[i], length=plen, flushed=plen,
                        submit_t=submit_t, admit_t=admit_t, blocks=blocks)
            a.out.append(int(first[i]))
            state["tokens"][a.row] = a.out[0]
            state["lengths"][a.row] = plen
            state["temps"][a.row] = req.temperature
            admitted.append(a)
        if admitted:
            perm = np.arange(B, dtype=np.int32)
            mask = np.zeros(B, bool)
            for i, a in enumerate(admitted):
                perm[a.row] = i
                mask[a.row] = True
            state["cache"] = self._scatter(state["cache"], kv["k"], kv["v"],
                                           jnp.asarray(perm),
                                           jnp.asarray(mask))
        state["prefill_s"] += time.perf_counter() - t0
        return admitted, deferred

    def _sample(self, logits, temps, key):
        return _sample_tokens(logits, jnp.asarray(temps, jnp.float32), key,
                              vocab=self.cfg.vocab)

    def _flush_blocks(self, a: _Active, cache) -> None:
        """Page freshly decoded KV out of the dense row whenever a full
        block has accumulated, so the refcounted pool (not the working
        cache) is the cache's durable home."""
        pool = self.kv_pool()
        bt = pool.block_tokens
        while a.length - a.flushed >= bt:
            e = a.flushed + bt
            kh = np.asarray(cache["k"][:, a.row, a.flushed:e])
            vh = np.asarray(cache["v"][:, a.row, a.flushed:e])
            try:
                a.blocks.append(pool.put_block(kh, vh))
            except KVPoolExhausted:
                return                       # defer: retry next boundary
            a.flushed = e

    def _run_continuous(self, source, sink) -> dict:
        """The continuous-batching loop: admit-as-slots-free, decode the
        whole active set each step with per-row positions, retire each row
        at its own ``max_new_tokens``."""
        B = self.max_batch
        state = {
            "cache": self._alloc_cache(),
            "tokens": np.zeros(B, np.int32),
            "lengths": np.zeros(B, np.int32),    # inactive rows: pos 0,
            "temps": np.zeros(B, np.float32),    # masked + greedy (harmless)
            "prefill_s": 0.0,
        }
        free_rows = deque(range(B))
        active: dict[int, _Active] = {}
        exhausted = False
        decode_s = 0.0
        steps = 0
        completed = 0
        last_touch = time.perf_counter()

        kv_starved = False                         # pool full: admissions
                                                   # wait for a retirement

        def retire(a: _Active) -> None:
            nonlocal completed, kv_starved
            kv_starved = False
            self.kv_pool().release(a.blocks)      # refcounts -> 0 -> freed
            now = time.perf_counter()
            sink(Completion(req_id=a.req.req_id, tokens=a.out,
                            prompt_len=len(a.req.prompt),
                            queued_s=a.admit_t - a.submit_t,
                            total_s=now - a.submit_t))
            active.pop(a.row)
            state["lengths"][a.row] = 0
            state["temps"][a.row] = 0.0
            state["tokens"][a.row] = 0
            free_rows.append(a.row)
            completed += 1

        while True:
            # -- admission: pull ready requests, admit per length group ---
            ready: list[tuple[Request, float]] = []
            while not kv_starved and len(ready) < len(free_rows) \
                    and not exhausted:
                req = source.poll(block=not active and not ready)
                if req is _EXHAUSTED:
                    exhausted = True
                    break
                if req is None:
                    break                          # nothing waiting right now
                if not req.req_id:
                    req.req_id = uuid.uuid4().hex[:12]
                if len(req.prompt) + req.max_new_tokens > self.max_context:
                    raise ValueError(
                        f"request {req.req_id}: prompt {len(req.prompt)} + "
                        f"max_new_tokens {req.max_new_tokens} exceeds "
                        f"max_context {self.max_context}")
                ready.append((req, time.perf_counter()))
            groups: dict[int, list[tuple[Request, float]]] = {}
            for item in ready:
                groups.setdefault(len(item[0].prompt), []).append(item)
            for group in groups.values():
                rows = [free_rows.popleft() for _ in group]
                admitted, deferred = self._admit_group(group, rows, state)
                for row in rows[len(admitted):]:
                    free_rows.append(row)
                for req in reversed(deferred):     # keep arrival order
                    source.push_back(req)
                if deferred:
                    kv_starved = True
                    exhausted = False              # pushed-back work remains
                for a in admitted:
                    active[a.row] = a
                    if len(a.out) >= a.req.max_new_tokens:
                        retire(a)                  # max_new_tokens == 1
            if kv_starved and not active:
                raise KVPoolExhausted(
                    "KV pool cannot hold a single request's prefill "
                    f"({self.kv_pool().stats()})")
            if not active:
                if exhausted:
                    break
                continue                           # idle: block in poll()

            # -- one decode step over the whole active set ----------------
            t0 = time.perf_counter()
            logits, state["cache"] = self._decode(
                self.params, state["cache"],
                jnp.asarray(state["tokens"][:, None]),
                jnp.asarray(state["lengths"]))
            self._key, sub = jax.random.split(self._key)
            nxt = np.asarray(self._sample(logits, state["temps"], sub))
            decode_s += time.perf_counter() - t0
            steps += 1

            for a in list(active.values()):
                state["lengths"][a.row] += 1
                a.length += 1
                tok = int(nxt[a.row])
                a.out.append(tok)
                state["tokens"][a.row] = tok
                self._flush_blocks(a, state["cache"])
                if len(a.out) >= a.req.max_new_tokens:
                    retire(a)

            # -- lease heartbeat for long-running requests ----------------
            if self.lease_ttl and \
                    time.perf_counter() - last_touch > self.lease_ttl / 2:
                pool = self.kv_pool()
                for a in active.values():
                    pool.touch(a.blocks)
                last_touch = time.perf_counter()

        return {"prefill_s": state["prefill_s"], "decode_s": decode_s,
                "decode_steps": steps, "completed": completed}

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def generate(self, reqs: list[Request]) -> dict:
        """Generate for a request list.  Continuous-capable families run
        the per-request scheduler (any number of requests — rows recycle);
        state-cache / sliding-window families use the lockstep batcher."""
        if not reqs:
            return {"outputs": [], "completions": [], "prefill_s": 0.0,
                    "decode_s": 0.0, "tokens_per_s": 0.0}
        if not self._continuous:
            return self._generate_static(reqs)
        for r in reqs:
            if not r.req_id:
                r.req_id = uuid.uuid4().hex[:12]
        completions: list[Completion] = []
        stats = self._run_continuous(_ListSource(list(reqs)),
                                     completions.append)
        by_id = {c.req_id: c for c in completions}
        outputs = [by_id[r.req_id].tokens for r in reqs]
        n_tok = sum(len(o) for o in outputs)
        return {"outputs": outputs, "completions": completions,
                "prefill_s": stats["prefill_s"],
                "decode_s": stats["decode_s"],
                "decode_steps": stats["decode_steps"],
                "tokens_per_s": n_tok / max(stats["decode_s"], 1e-9)}

    def serve_stream(self, store, request_topic: str,
                     result_topic: str | None = None, *,
                     data_store=None, timeout: float = 60.0,
                     result_ttl: float | None = 120.0,
                     result_groups: Sequence[str] | None = None) -> dict:
        """Serve until the request stream closes (or stays idle past
        ``timeout``).  Requests are stream items (optionally proxies);
        completions publish ONCE to ``result_topic`` — as ephemeral
        ``evict=True`` proxies through ``data_store`` when given (each
        result is consumed exactly once per group, then its slot is
        reclaimed), or inline otherwise — and fan out to every consumer
        group on the topic.  Each completion carries its metadata
        (``req_id``/``n_tokens``/latencies) on the event itself, so a
        ``payload=False`` tap (:func:`metrics_tap`) observes the serve
        loop without resolving a single result payload.  Groups named in
        ``result_groups`` are pre-subscribed before serving starts, so
        consumers attaching mid-stream (the client, a metrics dashboard)
        miss nothing.  Returns the scheduler's stats."""
        consumer = store.stream_consumer(request_topic, timeout=timeout)
        producer = (store.stream_producer(result_topic)
                    if result_topic else None)
        if producer is not None:
            for group in result_groups or ():
                store.connector.stream_subscribe(result_topic, group,
                                                 start="begin")
        local: list[Completion] = []

        def sink(c: Completion) -> None:
            if producer is None:
                local.append(c)
                return
            payload = {"req_id": c.req_id, "tokens": c.tokens,
                       "prompt_len": c.prompt_len,
                       "queued_s": c.queued_s, "total_s": c.total_s}
            meta = {"req_id": c.req_id, "n_tokens": len(c.tokens),
                    "queued_s": c.queued_s, "total_s": c.total_s}
            if data_store is not None:
                producer.append(data_store.proxy(payload, evict=True,
                                                 ttl=result_ttl),
                                meta=meta)
            else:
                producer.append(payload, meta=meta)

        try:
            stats = self._run_continuous(
                _StreamSource(consumer, timeout=timeout), sink)
        finally:
            consumer.close()        # return prefetched requests, if any
            if producer is not None:
                producer.close()
        stats["completions"] = local
        return stats

    # ------------------------------------------------------------------
    # lockstep fallback (state-cache + sliding-window families)
    # ------------------------------------------------------------------
    def _pad_prompts(self, reqs: list[Request]) -> tuple[np.ndarray, int]:
        max_len = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), max_len), np.int32)
        for i, r in enumerate(reqs):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks, max_len

    def _generate_static(self, reqs: list[Request]) -> dict:
        assert len(reqs) <= self.max_batch
        cfg = self.cfg
        toks, plen = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["vision_emb"] = jnp.zeros(
                (len(reqs), cfg.n_img_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (len(reqs), cfg.enc_frames, cfg.d_model),
                jnp.dtype(cfg.dtype))
        n_new = max(r.max_new_tokens for r in reqs)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        prefill_s = time.perf_counter() - t0

        # extend lockstep attention caches for the generated tokens (the
        # state-cache/ring families this path serves; the continuous
        # scheduler's families page through the KV pool instead)
        def grow(path, a):
            name = str(path[-1].key) if path else ""
            if name in ("k", "v") and a.ndim == 5 and not cfg.sliding_window:
                pad = np.zeros((*a.shape[:2], n_new, *a.shape[3:]), a.dtype)
                return jnp.concatenate([a, jnp.asarray(pad)], axis=2)
            return a
        cache = jax.tree_util.tree_map_with_path(grow, cache)

        out: list[list[int]] = [[] for _ in reqs]
        temps = np.asarray([r.temperature for r in reqs], np.float32)
        t0 = time.perf_counter()
        for t in range(n_new):
            self._key, sub = jax.random.split(self._key)
            nxt = self._sample(logits, temps, sub)[:, None]
            for i, token in enumerate(np.asarray(nxt)[:, 0]):
                if t < reqs[i].max_new_tokens:
                    out[i].append(int(token))
            if all(len(out[i]) >= r.max_new_tokens
                   for i, r in enumerate(reqs)):
                break
            logits, cache = self._decode(self.params, cache, nxt,
                                         jnp.asarray(plen + t, jnp.int32))
        decode_s = time.perf_counter() - t0
        n_tok = sum(len(o) for o in out)
        return {"outputs": out,
                "completions": [],
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "tokens_per_s": n_tok / max(decode_s, 1e-9)}

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        out = {"max_batch": self.max_batch, "max_context": self.max_context,
               "continuous": self._continuous}
        if self._kv_pool is not None:
            out["kv_pool"] = self._kv_pool.stats()
        return out

    def close(self) -> None:
        """Release the published-weights reference and any private KV
        store (freeing their arena slots)."""
        if self._weights_owned is not None:
            from repro.core.proxy import release

            try:
                release(self._weights_owned)
            except RuntimeError:
                pass                  # borrows still alive: owner keeps it
            self._weights_owned = None
        if self._own_kv_store and self._kv_store is not None:
            self._kv_store.close()
            self._kv_store = None
            self._kv_pool = None


def metrics_tap(store, result_topic: str, *, group: str = "metrics",
                start: str = "begin", timeout: float = 60.0):
    """Metadata-only consumer group over an engine's result stream.

    Subscribes ``group`` to ``result_topic`` with ``payload=False``: the
    tap iterates completion metadata (``req_id``/``n_tokens``/latencies)
    that :meth:`ServeEngine.serve_stream` attaches to every event, while
    the broker serves the actual result payloads only to the groups that
    resolve them.  The serve loop publishes each completion exactly once
    — adding (or removing) taps changes zero bytes on the data plane.

    Pass the returned consumer's group name in ``result_groups`` when
    starting ``serve_stream`` (or attach with ``start="begin"``, the
    default here) so no completions are missed.
    """
    return store.stream_consumer(result_topic, group=group, start=start,
                                 payload=False, timeout=timeout)
