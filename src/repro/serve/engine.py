"""Batched serving engine: prefill + decode with a static-batch scheduler.

Weights load lazily from a proxy-checkpoint manifest (each replica resolves
just-in-time; the paper's model-distribution path in §5.5) or from an
in-memory init.  Requests are padded/batched; decode runs a jitted
serve_step with a donated cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import build_model
from repro.train.checkpoints import ProxyCheckpointManager


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0   # 0 -> greedy


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 ckpts: ProxyCheckpointManager | None = None,
                 max_batch: int = 8) -> None:
        self.cfg = cfg
        self.model = build_model(cfg)
        if params is None:
            if ckpts is not None:  # lazy proxy restore of params only
                state = ckpts.restore()
                params = jax.tree.map(jnp.asarray, state["params"])
            else:
                params = self.model.init(jax.random.key(0))
        self.params = params
        self.max_batch = max_batch
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def _pad_prompts(self, reqs: list[Request]) -> tuple[np.ndarray, int]:
        max_len = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), max_len), np.int32)
        for i, r in enumerate(reqs):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks, max_len

    def generate(self, reqs: list[Request]) -> dict:
        """Greedy/temperature generation for a batch of requests."""
        assert len(reqs) <= self.max_batch
        cfg = self.cfg
        toks, plen = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["vision_emb"] = jnp.zeros(
                (len(reqs), cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (len(reqs), cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
        n_new = max(r.max_new_tokens for r in reqs)

        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        prefill_s = time.time() - t0

        # grow attention caches to hold the generated tokens
        def grow(path, a):
            name = str(path[-1].key) if path else ""
            if name in ("k", "v") and a.ndim == 5 and not cfg.sliding_window:
                pad = np.zeros((*a.shape[:2], n_new, *a.shape[3:]), a.dtype)
                return jnp.concatenate([a, jnp.asarray(pad)], axis=2)
            return a
        cache = jax.tree_util.tree_map_with_path(grow, cache)

        out = [[] for _ in reqs]
        key = jax.random.key(1234)
        t0 = time.time()
        for t in range(n_new):
            if reqs[0].temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, :cfg.vocab] / reqs[0].temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, :cfg.vocab], axis=-1)
            nxt = nxt.astype(jnp.int32)[:, None]
            for i, token in enumerate(np.asarray(nxt)[:, 0]):
                if t < reqs[i].max_new_tokens:
                    out[i].append(int(token))
            logits, cache = self._decode(self.params, cache, nxt,
                                         jnp.asarray(plen + t, jnp.int32))
        decode_s = time.time() - t0
        return {"outputs": out,
                "prefill_s": prefill_s,
                "decode_s": decode_s,
                "tokens_per_s": len(reqs) * n_new / max(decode_s, 1e-9)}
