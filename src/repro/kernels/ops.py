"""jit'd public wrappers for the Pallas kernels.

Each op is differentiable via ``jax.custom_vjp`` whose backward pass
recomputes through the pure-jnp oracle (``ref.py``) — the standard
flash-attention trick of trading recompute for never materializing the
forward's O(S^2) intermediates.  Forward runs the Pallas kernel
(``interpret=True`` on CPU; compiled on TPU).

Model code reaches these through ``cfg.attention_impl == "pallas"``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

# interpret=True executes kernel bodies on CPU; on a real TPU runtime set
# REPRO_PALLAS_COMPILED=1 to lower them natively.
_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILED", "0") != "1"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0):
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, interpret=_INTERPRET)


def _fa_fwd(q, k, v, causal, window, q_offset):
    return flash_attention(q, k, v, causal, window, q_offset), (q, k, v)


def _fa_bwd(causal, window, q_offset, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: R.flash_attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# decode attention (inference only; no vjp needed, but harmless to add)
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, length):
    return _decode_pallas(q, k_cache, v_cache, length, interpret=_INTERPRET)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_scan(x, dt, a_log, b, c, chunk=128):
    return _ssd_pallas(x, dt, a_log, b, c, chunk=chunk, interpret=_INTERPRET)


def _ssd_fwd(x, dt, a_log, b, c, chunk):
    return ssd_scan(x, dt, a_log, b, c, chunk), (x, dt, a_log, b, c)


def _ssd_bwd(chunk, res, g):
    x, dt, a_log, b, c = res
    _, vjp = jax.vjp(lambda *a: R.ssd_scan_ref(*a), x, dt, a_log, b, c)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)
