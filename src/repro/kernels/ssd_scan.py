"""Mamba2 SSD chunked scan — Pallas TPU.

The SSD dual form (arXiv:2405.21060 §6) maps naturally onto the MXU: per
chunk, three small matmuls (C Bᵀ, masked-decay weighting, state in/out
contractions) over (Q, N)/(Q, P) tiles, plus an O(P x N) recurrent state that
persists in VMEM scratch across the innermost (sequential) chunk dimension —
the TPU analog of the paper's SM-resident recurrence.

Grid: (B, H, n_chunks).  Blocks: x (Q, P), b/c (Q, N), dta (Q,) — with
Q=chunk (128-256), P=64, N=64-128 every tile is MXU-aligned and the VMEM
working set is < 1 MB.  ngroups=1: B/C blocks are shared across the H grid
dimension (index map drops h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; >= 0.5 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, dta_ref, b_ref, c_ref, y_ref, state_sc, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_sc[...] = jnp.zeros_like(state_sc)

    x = x_ref[0, :, 0, :]                       # (Q, P)
    dta = dta_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    b = b_ref[0, :, :].astype(jnp.float32)      # (Q, N)
    c = c_ref[0, :, :].astype(jnp.float32)      # (Q, N)

    cum = jnp.cumsum(dta)                       # (Q,)
    # within-chunk decay L[q, s] = exp(cum[q] - cum[s]) for q >= s
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * decay                          # (Q, Q)
    xf = x.astype(jnp.float32)
    y_diag = jax.lax.dot_general(w, xf, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    state = state_sc[...]                       # (P, N)
    c_dec = c * jnp.exp(cum)[:, None]           # (Q, N)
    y_off = jax.lax.dot_general(c_dec, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: s' = exp(sum dta) * s + sum_q exp(cum[-1]-cum[q]) x_q b_qᵀ
    b_dec = b * jnp.exp(cum[-1] - cum)[:, None]  # (Q, N)
    inject = jax.lax.dot_general(xf, b_dec, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    state_sc[...] = jnp.exp(cum[-1]) * state + inject


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b, c, *, chunk=128, interpret=True):
    """x: (B, L, H, P); dt: (B, L, H); a_log: (H,); b, c: (B, L, N).

    dt is folded into x and dta outside the kernel (cheap elementwise);
    the kernel does the chunked scan proper.  Returns y: (B, L, H, P).
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    n_c = l // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * a            # (B, L, H)
    xdt = (x * dt[..., None].astype(x.dtype))   # (B, L, H, P)

    grid = (bs, h, n_c)
    kernel = functools.partial(_kernel, chunk=chunk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, l, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, dta, b, c)
