"""Single-token (decode) attention over a KV cache — Pallas TPU.

Flash-decoding-style split-K: the cache's sequence axis is tiled into
``block_s`` blocks; the grid walks them innermost while (m, l, acc) online-
softmax state for all Q heads of one KV head persists in VMEM scratch.
Entries at index >= ``length`` (ring validity) are masked.

q is tiny ((G, HD) per grid step), so the kernel is bandwidth-bound on the
K/V stream — exactly the regime the roofline analysis flags for decode
shapes; the block size keeps each VMEM tile at block_s * HD * 2B.

Grid: (B, KV, nS); ``length`` arrives as a scalar-prefetch operand (SMEM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; >= 0.5 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            block_s, n_s, scale):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0, :, :]                      # (G, HD) fp32-upcast below
    k = k_ref[0, :, 0, :]                      # (block_s, HD)
    v = v_ref[0, :, 0, :]                      # (block_s, HD)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = pos < len_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)

    l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_cur

    @pl.when(si == n_s - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_sc[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, block_s=256,
                     interpret=True):
    """q: (B, 1, H, HD); caches: (B, S, KV, HD); length: scalar int32."""
    b, _, h, hd = q.shape
    s_c, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    block_s = min(block_s, s_c)
    assert s_c % block_s == 0, (s_c, block_s)
    n_s = s_c // block_s

    qg = q.reshape(b, kv, g, hd)
    grid = (b, kv, n_s)
    kernel = functools.partial(_kernel, block_s=block_s, n_s=n_s, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd),
                             lambda bi, ki, si, *_: (bi, ki, 0, 0)),
                pl.BlockSpec((1, block_s, 1, hd),
                             lambda bi, ki, si, *_: (bi, si, ki, 0)),
                pl.BlockSpec((1, block_s, 1, hd),
                             lambda bi, ki, si, *_: (bi, si, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda bi, ki, si, *_: (bi, ki, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32)[None], qg, k_cache, v_cache)
    return out.reshape(b, 1, h, hd)
