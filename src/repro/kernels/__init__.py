"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with ops.py as the jit'd differentiable wrapper and ref.py as the
pure-jnp oracle used by tests/test_kernels.py allclose sweeps.

CPU container: interpret=True (validation); TPU: REPRO_PALLAS_COMPILED=1.
"""
from repro.kernels.ops import decode_attention, flash_attention, ssd_scan

__all__ = ["flash_attention", "decode_attention", "ssd_scan"]
