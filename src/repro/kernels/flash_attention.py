"""Flash attention (causal, GQA, optional sliding window) — Pallas TPU.

TPU-native adaptation (DESIGN.md §2): tiles are MXU-aligned (q/k blocks are
multiples of 128 where shapes allow), the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across the innermost
(arbitrary-semantics) K-block grid dimension, and the K/V stream stays in
(block_k, HD) tiles so the working set is ~4 * block * HD * dtype bytes —
far under v5e VMEM at the default 128x128 tiling.

Grid: (B, H, nQ, nK), nK innermost/sequential.  Causal skipping is done by
masking; a production variant would prune fully-masked K blocks with a
scalar-prefetch grid map (noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; >= 0.5 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            block_q, block_k, n_k, causal, window, q_offset, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, :, 0, :]                      # (block_q, HD)
    k = k_ref[0, :, 0, :]                      # (block_k, HD)
    v = v_ref[0, :, 0, :]                      # (block_k, HD)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]                          # (block_q,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)

    l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_cur

    @pl.when(ik == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_sc[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "block_q",
                              "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128, interpret=True):
    """q: (B, Sq, H, HD); k, v: (B, Skv, KV, HD) -> (B, Sq, H, HD)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    n_q, n_k = sq // block_q, skv // block_k

    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_k=n_k, causal=causal,
        window=window, q_offset=q_offset, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
