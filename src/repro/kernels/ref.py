"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q: (B, Sq, H, HD); k, v: (B, Skv, KV, HD) -> (B, Sq, H, HD)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p).astype(q.dtype)  # fully-masked rows
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, h, hd)


def decode_attention_ref(q, k_cache, v_cache, length):
    """q: (B, 1, H, HD); caches: (B, S, KV, HD); length: scalar valid count."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(k_cache.shape[1]) < length
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache)
    return o.reshape(b, 1, h, hd)


def ssd_scan_ref(x, dt, a_log, b, c):
    """Sequential SSD recurrence (exact; O(L) state updates).

    x: (B, L, H, P); dt: (B, L, H); a_log: (H,); b, c: (B, L, N)
    -> y: (B, L, H, P)
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t.astype(jnp.float32) * a)[..., None, None]
        inject = jnp.einsum("bhp,bn->bhpn",
                            (x_t * dt_t[..., None]).astype(jnp.float32),
                            b_t.astype(jnp.float32))
        state = decay * state + inject
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t.astype(jnp.float32))
        return state, y_t

    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (jnp.moveaxis(x, 1, 0),
                                    jnp.moveaxis(dt, 1, 0),
                                    jnp.moveaxis(b, 1, 0),
                                    jnp.moveaxis(c, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
