"""repro.analysis — the two-headed correctness tool.

Head 1, ``proxylint`` (:mod:`repro.analysis.lint`): an AST-based static
analysis pass over the source tree whose rules R1-R7 are distilled from
this repo's own bug history (wall-clock lease arithmetic, borrowed shm
views outliving their slot, multi-resolved ``evict=True`` ephemerals,
``-O``-stripped asserts, blocking calls on the event loop, non-idempotent
ops inside retry wrappers).  Run it as::

    PYTHONPATH=src python -m repro.analysis.lint src/

Head 2, the runtime sanitizer (:mod:`repro.analysis.sanitize`): enabled by
``REPRO_SANITIZE=1`` (or per-store with ``Store(..., sanitize=True)``), it
poisons freed arena chunks, quarantines them a generation before reuse,
tracks exported zero-copy views, and mirrors every incref/decref in a
client-side ledger cross-checked against server counts at ``Store.close``.

Both heads are stdlib-only: importing this package never pulls numpy/jax,
so the lint CI job runs without installing the runtime dependencies.
"""
from repro.analysis.sanitize import (SanitizerError, SanitizerWarning,
                                     enabled)

__all__ = ["SanitizerError", "SanitizerWarning", "enabled"]
