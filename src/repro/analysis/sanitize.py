"""Runtime sanitizer primitives (stdlib-only; the hooks live in core).

The proxy paradigm moves every lifecycle bug far from its cause: a
use-after-evict in one process corrupts a consumer in another, a leaked
incref shows up as memory growth hours later, a double-decref kills a
sibling's data.  This module holds the shared machinery the core layers
hook into when sanitizing is on:

* :func:`enabled` — the ``REPRO_SANITIZE`` env toggle (``Store`` also takes
  ``sanitize=True`` per instance);
* :class:`RefLedger` — a client-side mirror of every incref/decref this
  process performs, with creation/release backtraces, raising
  ``double-decref`` / ``use-after-evict`` at the call site and reporting
  ``refcount-leak`` candidates (cross-checked against server counts) at
  ``Store.close()``;
* poison helpers — freed arena chunks are filled with ``0xDE`` and
  quarantined a generation before reuse, so a stale zero-copy view reads
  an unmistakable pattern instead of silently-recycled bytes;
  :func:`check_view` (and the ``PSJ2`` magic check in ``deserialize``)
  turn that pattern into a named ``poisoned-read`` diagnostic.

Every sanitizer failure is a :class:`SanitizerError` carrying a stable
``diagnostic`` name (``use-after-free-view``, ``refcount-leak``,
``double-decref``, ``use-after-evict``, ``poisoned-read``,
``non-idempotent-retry``) so tests and CI can match on the class of bug,
not on message wording.
"""
from __future__ import annotations

import os
import threading
import traceback
from typing import Any, Iterable

POISON_BYTE = 0xDE
_POISON_RUN = bytes([POISON_BYTE]) * 8


def enabled() -> bool:
    """True when the process-wide sanitizer toggle is on."""
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in (
        "1", "true", "yes", "on")


class SanitizerError(RuntimeError, LookupError):
    """A sanitizer detection.  ``diagnostic`` is the stable class name.

    Subclasses ``LookupError`` too: a ``use-after-evict`` fires on paths
    whose un-sanitized failure mode is a ``LookupError`` miss, and callers
    matching on that must keep working under ``REPRO_SANITIZE=1``.
    """

    def __init__(self, diagnostic: str, message: str) -> None:
        self.diagnostic = diagnostic
        super().__init__(f"[{diagnostic}] {message}")


class SanitizerWarning(UserWarning):
    """Non-fatal sanitizer report (leak candidates at ``Store.close``)."""


def borrow_site(skip: int = 2, limit: int = 8) -> str:
    """Short formatted stack naming where a borrow/acquire happened,
    ending at the caller ``skip`` frames up (dropping sanitizer frames)."""
    frames = traceback.extract_stack()
    frames = frames[:-skip] if skip else frames
    return "".join(traceback.format_list(frames[-limit:])) or "  <unknown>\n"


def looks_poisoned(buf: Any) -> bool:
    """Heuristic: does this buffer start with the arena poison pattern?"""
    try:
        mv = memoryview(buf)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
    except (TypeError, ValueError):
        return False
    if mv.nbytes == 0:
        return False
    head = bytes(mv[:len(_POISON_RUN)])
    return head == _POISON_RUN[:len(head)]


def check_view(buf: Any, what: str = "view") -> None:
    """Raise ``poisoned-read`` if ``buf`` reads as poisoned memory — the
    signature of holding a zero-copy view across its slot's free."""
    if looks_poisoned(buf):
        raise SanitizerError(
            "poisoned-read",
            f"{what} reads as 0xDE poison: the arena chunk behind it was "
            f"freed (and quarantined) while this reference was still live. "
            f"Pin the key with a refcount/lease, or serialize.materialize "
            f"the object before the last decref/evict.")


class _Entry:
    __slots__ = ("acquired", "released", "transferred", "dead",
                 "acquire_site", "release_site")

    def __init__(self) -> None:
        self.acquired = 0
        self.released = 0
        self.transferred = 0
        self.dead = False
        self.acquire_site: str | None = None
        self.release_site: str | None = None


class RefLedger:
    """Client-side mirror of this process's refcount traffic for one store.

    ``acquired`` counts local increfs (proxy creation, clones, explicit
    ``Store.incref``); ``transferred`` counts increfs made on behalf of a
    pickled sibling (the reference travels with the bytes and is released
    by whoever unpickles them — possibly this same process, so transfers
    raise the local release budget rather than being excluded from it);
    ``released`` counts local decrefs.  A release beyond
    ``acquired + transferred`` on a locally-acquired key is a
    ``double-decref``; an incref on a key this process watched hit zero is
    a ``use-after-evict``; a positive balance at close is a
    ``refcount-leak`` candidate (confirmed against the server's count).
    """

    def __init__(self, name: str) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._entries: dict[Any, _Entry] = {}

    def _entry(self, key: Any) -> _Entry:
        e = self._entries.get(key)
        if e is None:
            e = self._entries[key] = _Entry()
        return e

    def incref(self, key: Any, n: int = 1, *, transfer: bool = False) -> None:
        site = borrow_site(skip=3)
        with self._lock:
            e = self._entry(key)
            if e.dead:
                raise SanitizerError(
                    "use-after-evict",
                    f"store {self.name!r}: incref on key {key} after this "
                    f"process observed its count hit zero (the channel "
                    f"evicted it).\nLast released at:\n"
                    f"{e.release_site or '  <unknown>'}")
            if transfer:
                e.transferred += n
            else:
                e.acquired += n
            if e.acquire_site is None:
                e.acquire_site = site

    def decref(self, key: Any, n: int = 1) -> None:
        """Record (and vet) a local release BEFORE it hits the channel."""
        site = borrow_site(skip=3)
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                # reference acquired in another process (a pickled-in
                # sibling): nothing to vet locally
                return
            budget = e.acquired + e.transferred
            if e.acquired and e.released + n > budget:
                raise SanitizerError(
                    "double-decref",
                    f"store {self.name!r}: key {key} released "
                    f"{e.released + n} times against {e.acquired} local + "
                    f"{e.transferred} transferred acquisition(s).\n"
                    f"First acquired at:\n{e.acquire_site or '  <unknown>'}"
                    f"Previous release at:\n{e.release_site or '  <unknown>'}")
            e.released += n
            e.release_site = site

    def mark_dead(self, key: Any) -> None:
        """The channel reported count zero for ``key`` (it is gone)."""
        with self._lock:
            self._entry(key).dead = True

    def is_dead(self, key: Any) -> bool:
        with self._lock:
            e = self._entries.get(key)
            return bool(e and e.dead)

    def leak_candidates(self) -> list[tuple[Any, int, str]]:
        """``(key, balance, acquire_site)`` for keys whose local
        acquisitions outnumber releases + transfers."""
        with self._lock:
            out = []
            for key, e in self._entries.items():
                balance = e.acquired - e.released - e.transferred
                if balance > 0 and not e.dead:
                    out.append((key, balance,
                                e.acquire_site or "  <unknown>\n"))
            return out

    def format_leaks(self, confirmed: Iterable[tuple[Any, int, int, str]],
                     ) -> str:
        confirmed = list(confirmed)
        lines = [f"[refcount-leak] store {self.name!r}: "
                 f"{len(confirmed)} leaked reference(s) at close"]
        for key, balance, server, site in confirmed:
            lines.append(
                f"  key {key}: {balance} unreleased local ref(s), server "
                f"count {server}; first acquired at:\n{site}")
        return "\n".join(lines)
