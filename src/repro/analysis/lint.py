"""proxylint — AST rules distilled from this repo's own bug history.

Every rule encodes a defect class that was actually fixed by hand in a
past PR and must not regress as the codebase scales out:

* **R1 wallclock** — ``time.time()`` anywhere in the tree.  Wall clock in
  lease/deadline/timeout arithmetic broke leases under NTP steps twice
  (the PR 4 lease fix, re-fixed for heartbeats in PR 7); deadline math
  must use ``time.monotonic()``/``perf_counter()``.  Pure timestamps
  (manifests, logs) are allowlisted with ``# lint: wallclock-ok``.
* **R2 borrowed-view escape** — a value read from lifecycle-bound channel
  memory (``Arena.read``/``slot_view``/``block_view``) returned from a
  function that also drops references (``decref``/``evict``/``free``),
  without ``serialize.materialize`` in between.  The PR 5 bug class: the
  old per-object-segment design was only accidentally safe; arena chunks
  recycle under live views.
* **R3 ephemeral multi-resolve** — an ``evict=True`` proxy resolved more
  than once on a path, or pickled into a fan-out loop.  The PR 3 bug
  class (first resolve used to break every sibling; ephemerals still hold
  exactly one reference per sibling, so double-resolving one is a bug).
* **R4 bare assert** — ``assert`` guarding a runtime invariant inside
  ``src/repro/core/``: stripped under ``python -O``, so connector
  argument / frame-parsing / slot-state checks silently vanish.
* **R5 blocking-in-async** — ``time.sleep``, sync socket ops, or file I/O
  inside an ``async def`` body of the event-loop modules
  (``kv_tcp.py``/``fabric.py``/``endpoint.py``): one blocking call stalls
  every multiplexed connection on the loop.
* **R6 non-idempotent retry** — ``put2``/``decref``/``s_append``-family
  ops inside a retry wrapper.  The PR 7 rule: a lost-ack retry of a
  non-idempotent op double-applies it (double-decref kills sibling data).
* **R7 unclosed stream consumer** — a consumer built by
  ``stream_consumer``/``StreamConsumer``/``ProxyStream`` (or the
  ``metrics_tap``/``monitor_updates`` helpers) iterated without a
  ``with`` block or a reachable ``.close()``.  The PR 9 bug class: a
  consumer abandoned mid-stream leaves its prefetched-but-undelivered
  events unacked, parking their group references (and the payloads'
  broker refcounts) until the TTL backstop reaps them.

Allowlist convention: a ``# lint: <tag>`` comment on the flagged line or
the line above suppresses the finding (tags: ``wallclock-ok``,
``borrow-ok``, ``evict-ok``, ``assert-ok``, ``blocking-ok``,
``retry-ok``, ``stream-ok``).

Run: ``PYTHONPATH=src python -m repro.analysis.lint src/`` — exits
non-zero on any finding.  Stdlib-only by design: the CI lint job needs no
runtime dependencies.
"""
from __future__ import annotations

import argparse
import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

ALLOW_TAGS = {
    "R1": "wallclock-ok",
    "R2": "borrow-ok",
    "R3": "evict-ok",
    "R4": "assert-ok",
    "R5": "blocking-ok",
    "R6": "retry-ok",
    "R7": "stream-ok",
}

# R2: calls that hand out views aliasing lifecycle-bound channel memory
_BORROW_SOURCES = {"read", "block_view", "slot_view", "reserve_direct"}
# R2: calls that can drop the last reference (and recycle the memory)
_LIFECYCLE_DROPS = {"decref", "mdecref", "decref_batch", "evict", "mevict",
                    "evict_batch", "free", "request_free"}
# R5: blocking callables by attribute/name
_BLOCKING_ATTRS = {"read_bytes", "write_bytes", "read_text", "write_text",
                   "recv", "recv_into", "sendall", "sendto", "accept"}
_R5_FILES = {"kv_tcp.py", "fabric.py", "endpoint.py"}
# R6: ops that must never ride a transparent retry
_NONIDEMPOTENT = {"put2", "mput2", "decref", "mdecref", "s_append",
                  "stream_append"}
_RETRY_WRAPPERS = {"with_retries", "retry", "retrying", "with_retry"}
# R7: calls that build a group-cursor stream consumer
_CONSUMER_SOURCES = {"stream_consumer", "StreamConsumer", "ProxyStream",
                     "metrics_tap", "monitor_updates"}
# R7: builtins that drain an iterable passed by name
_DRAINERS = {"list", "tuple", "sorted", "next", "iter", "sum", "max", "min"}


@dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


def _call_name(node: ast.AST) -> str | None:
    """Trailing name of a call target: ``a.b.c(...)`` -> ``c``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` -> ``"a.b.c"`` (Names/Attributes only)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str, *, core: bool) -> None:
        self.path = path
        self.lines = source.splitlines()
        self.core = core
        self.basename = Path(path).name
        self.findings: list[Finding] = []
        # import aliases: local name -> canonical dotted name
        self.aliases: dict[str, str] = {}
        # nested-function context: (node, is_async) innermost last
        self._funcs: list[tuple[ast.AST, bool]] = []
        self._loop_depth = 0
        self._retry_depth = 0

    # -- infrastructure ------------------------------------------------------
    def _allowed(self, node: ast.AST, rule: str) -> bool:
        tag = f"lint: {ALLOW_TAGS[rule]}"
        for ln in (node.lineno, node.lineno - 1):
            if 1 <= ln <= len(self.lines) and tag in self.lines[ln - 1]:
                return True
        return False

    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        if not self._allowed(node, rule):
            self.findings.append(Finding(self.path, node.lineno,
                                         node.col_offset, rule, message))

    def _canon(self, node: ast.AST) -> str | None:
        """Canonical dotted name of a call target, through import aliases."""
        dotted = _dotted(node)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.aliases[alias.asname or alias.name.split(".")[0]] = \
                alias.name if alias.asname else alias.name.split(".")[0]
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module:
            for alias in node.names:
                self.aliases[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
        self.generic_visit(node)

    # -- scope/loop tracking -------------------------------------------------
    def _visit_func(self, node, is_async: bool) -> None:
        retry_deco = any(
            (_call_name(d.func if isinstance(d, ast.Call) else d) or "")
            in _RETRY_WRAPPERS for d in node.decorator_list)
        self._funcs.append((node, is_async))
        if retry_deco:
            self._retry_depth += 1
        self._scan_function(node)
        self.generic_visit(node)
        if retry_deco:
            self._retry_depth -= 1
        self._funcs.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_func(node, is_async=False)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_func(node, is_async=True)

    def _visit_loop(self, node) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = visit_While = visit_AsyncFor = _visit_loop

    # -- R4: bare asserts in core -------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        if self.core:
            self._flag(node, "R4",
                       "bare assert guards a runtime invariant (stripped "
                       "under python -O); raise ValueError/RuntimeError")
        self.generic_visit(node)

    # -- R1 / R5 / R6 (call-site rules) -------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        canon = self._canon(node.func)
        name = _call_name(node.func)

        if canon == "time.time":
            ctx = self._stmt_context(node)
            if ctx in ("arith", "compare"):
                self._flag(node, "R1",
                           "time.time() feeds deadline/timeout arithmetic "
                           "— wall clock steps under NTP; use "
                           "time.monotonic() or time.perf_counter()")
            else:
                self._flag(node, "R1",
                           "time.time() is wall clock; if this is a pure "
                           "timestamp (manifest/log), allowlist with "
                           "'# lint: wallclock-ok', otherwise use "
                           "time.monotonic()")

        if self._in_async() and self.basename in _R5_FILES:
            blocking = None
            if canon == "time.sleep":
                blocking = "time.sleep"
            elif isinstance(node.func, ast.Name) and node.func.id == "open":
                blocking = "open()"
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _BLOCKING_ATTRS:
                blocking = f".{node.func.attr}()"
            if blocking:
                self._flag(node, "R5",
                           f"blocking call {blocking} inside an async def "
                           f"stalls every connection multiplexed on this "
                           f"event loop; await the async variant or punt "
                           f"to an executor")

        if name in _NONIDEMPOTENT:
            if self._retry_depth:
                self._flag(node, "R6",
                           f"non-idempotent op {name!r} inside a retry "
                           f"wrapper: a lost-ack retry double-applies it "
                           f"(fail fast instead)")
            for kw in node.keywords:
                if kw.arg == "retry" \
                        and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    self._flag(node, "R6",
                               f"non-idempotent op {name!r} called with "
                               f"retry=True")
        if name in _RETRY_WRAPPERS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call):
                        sub_name = _call_name(sub.func)
                        if sub_name in _NONIDEMPOTENT:
                            self._flag(sub, "R6",
                                       f"non-idempotent op {sub_name!r} "
                                       f"wrapped in {name}(): a lost-ack "
                                       f"retry double-applies it")
        # literal {"op": "decref"}-style requests with retry=True
        if name == "request":
            self._check_request_retry(node)
        self.generic_visit(node)

    def _check_request_retry(self, node: ast.Call) -> None:
        retry_true = any(
            kw.arg == "retry" and isinstance(kw.value, ast.Constant)
            and kw.value.value is True for kw in node.keywords)
        if not (retry_true and node.args):
            return
        msg = node.args[0]
        if isinstance(msg, ast.Dict):
            for k, v in zip(msg.keys, msg.values):
                if isinstance(k, ast.Constant) and k.value == "op" \
                        and isinstance(v, ast.Constant) \
                        and v.value in _NONIDEMPOTENT:
                    self._flag(node, "R6",
                               f"non-idempotent op {v.value!r} requested "
                               f"with retry=True")

    def _in_async(self) -> bool:
        return bool(self._funcs) and self._funcs[-1][1]

    def _stmt_context(self, node: ast.AST) -> str:
        """'arith' / 'compare' / 'plain' for a call, from parent links."""
        cur = getattr(node, "_lint_parent", None)
        while cur is not None and not isinstance(cur, ast.stmt):
            if isinstance(cur, ast.BinOp) and isinstance(
                    cur.op, (ast.Add, ast.Sub)):
                return "arith"
            if isinstance(cur, ast.Compare):
                return "compare"
            cur = getattr(cur, "_lint_parent", None)
        return "plain"

    # -- R2 / R3 (function-scoped dataflow heuristics) -----------------------
    def _scan_function(self, func) -> None:
        borrow_names: dict[str, int] = {}     # name -> lineno of the borrow
        materialized: set[str] = set()
        evict_names: dict[str, int] = {}      # name -> lineno of creation
        resolves: dict[str, list[int]] = {}   # evict name -> resolve linenos
        drops = False
        own_loops: list[tuple[int, int]] = []  # (lineno, end_lineno) spans
        # R7 state: consumer name -> creation line; names closed/managed
        consumers: dict[str, int] = {}
        closed: set[str] = set()
        managed: set[str] = set()
        drained: list[tuple[ast.AST, str | None]] = []  # (site, name|anon)

        def in_own_loop(n: ast.AST) -> bool:
            return any(a <= n.lineno <= b for a, b in own_loops)

        def walk_shallow(root):
            """Pre-order, SOURCE-ORDER descendants of ``root`` excluding
            nested function bodies (those are scanned on their own visit).
            Source order matters: resolves/pickles of an evict proxy must
            see the assignment that created it."""
            stack = list(ast.iter_child_nodes(root))[::-1]
            while stack:
                n = stack.pop()
                yield n
                if not isinstance(n, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda)):
                    stack.extend(list(ast.iter_child_nodes(n))[::-1])

        for sub in walk_shallow(func):
            if isinstance(sub, (ast.For, ast.While, ast.AsyncFor)):
                own_loops.append((sub.lineno, sub.end_lineno or sub.lineno))
            if isinstance(sub, ast.Assign) and isinstance(
                    sub.value, ast.Call):
                cname = _call_name(sub.value.func)
                targets: list[str] = []
                for t in sub.targets:
                    if isinstance(t, ast.Name):
                        targets.append(t.id)
                    elif isinstance(t, ast.Tuple):
                        targets.extend(e.id for e in t.elts
                                       if isinstance(e, ast.Name))
                if cname in _BORROW_SOURCES:
                    for t in targets:
                        borrow_names[t] = sub.lineno
                if cname in _CONSUMER_SOURCES:
                    for t in targets:
                        consumers[t] = sub.lineno
                if cname == "materialize":
                    materialized.update(targets)
                if any(kw.arg == "evict"
                       and isinstance(kw.value, ast.Constant)
                       and kw.value.value is True
                       for kw in sub.value.keywords):
                    for t in targets:
                        evict_names[t] = sub.lineno
            # R7: sites that drain a consumer, and the escape hatches
            if isinstance(sub, (ast.For, ast.AsyncFor)):
                if isinstance(sub.iter, ast.Name):
                    drained.append((sub, sub.iter.id))
                elif isinstance(sub.iter, ast.Call) \
                        and _call_name(sub.iter.func) in _CONSUMER_SOURCES:
                    drained.append((sub, None))
            if isinstance(sub, ast.comprehension):
                if isinstance(sub.iter, ast.Name):
                    drained.append((sub.iter, sub.iter.id))
                elif isinstance(sub.iter, ast.Call) \
                        and _call_name(sub.iter.func) in _CONSUMER_SOURCES:
                    drained.append((sub.iter, None))
            if isinstance(sub, (ast.With, ast.AsyncWith)):
                for item in sub.items:
                    if isinstance(item.context_expr, ast.Name):
                        managed.add(item.context_expr.id)
            if isinstance(sub, ast.Call):
                cname = _call_name(sub.func)
                if isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr == "close" \
                        and isinstance(sub.func.value, ast.Name):
                    closed.add(sub.func.value.id)
                if cname in _DRAINERS and sub.args:
                    a = sub.args[0]
                    if isinstance(a, ast.Name):
                        drained.append((sub, a.id))
                    elif isinstance(a, ast.Call) \
                            and _call_name(a.func) in _CONSUMER_SOURCES:
                        drained.append((sub, None))
                if cname in _LIFECYCLE_DROPS:
                    drops = True
                if cname == "materialize":
                    for a in sub.args:
                        if isinstance(a, ast.Name):
                            materialized.add(a.id)
                # R3: resolution sites + pickle fan-out of evict proxies
                if cname in ("extract", "resolve", "asarray", "array"):
                    for a in sub.args:
                        if isinstance(a, ast.Name) and a.id in evict_names:
                            w = 2 if in_own_loop(sub) else 1
                            resolves.setdefault(a.id, []).extend(
                                [sub.lineno] * w)
                if cname == "dumps" and in_own_loop(sub):
                    for a in sub.args:
                        if isinstance(a, ast.Name) and a.id in evict_names:
                            self._flag(
                                sub, "R3",
                                f"evict=True proxy {a.id!r} pickled inside "
                                f"a loop: each pickle increfs, but a "
                                f"fan-out should mint one sibling per "
                                f"consumer (proxy_batch / clone)")

        if drops:
            for sub in walk_shallow(func):
                if isinstance(sub, (ast.Return, ast.Yield)) \
                        and isinstance(sub.value, ast.Name):
                    nm = sub.value.id
                    if nm in borrow_names and nm not in materialized:
                        self._flag(
                            sub, "R2",
                            f"{nm!r} aliases lifecycle-bound channel "
                            f"memory (borrowed at line "
                            f"{borrow_names[nm]}) and escapes a scope "
                            f"that drops references; call "
                            f"serialize.materialize({nm}) before the "
                            f"last decref/evict")
        for site, nm in drained:
            if nm is None:
                self._flag(site, "R7",
                           "stream consumer built inline and drained with "
                           "no handle to close(): prefetched-but-"
                           "undelivered events stay unacked, parking "
                           "their group references — bind it in a `with` "
                           "block")
            elif nm in consumers and nm not in closed \
                    and nm not in managed:
                self._flag(site, "R7",
                           f"stream consumer {nm!r} (created line "
                           f"{consumers[nm]}) is iterated without "
                           f"close(): use `with` or try/finally close() "
                           f"so prefetched-but-undelivered events are "
                           f"requeued to the group")
        for nm, sites in resolves.items():
            if len(sites) >= 2:
                # walk order is stack-based, not source order: flag the
                # second resolve BY LINE so its allowlist comment matches
                self._flag_at(
                    sorted(sites)[1], "R3",
                    f"evict=True proxy {nm!r} (created line "
                    f"{evict_names[nm]}) is resolved more than once on "
                    f"this path; the first resolve consumes its "
                    f"reference — use into_owned()/borrow() for reuse")

    def _flag_at(self, lineno: int, rule: str, message: str) -> None:
        shim = ast.Pass(lineno=lineno, col_offset=0)
        self._flag(shim, rule, message)


def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint one source string; ``path`` decides file-scoped rules."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "E0",
                        f"syntax error: {e.msg}")]
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._lint_parent = parent  # type: ignore[attr-defined]
    norm = str(path).replace("\\", "/")
    core = "repro/core/" in norm
    linter = _Linter(str(path), source, core=core)
    linter.visit(tree)
    linter.findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return linter.findings


def lint_file(path: str | Path) -> list[Finding]:
    return lint_source(Path(path).read_text(encoding="utf-8"), str(path))


def iter_py_files(paths: list[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def lint_paths(paths: list[str]) -> list[Finding]:
    findings: list[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_file(f))
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="proxylint: lifecycle/correctness rules R1-R7")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print only the summary line")
    args = ap.parse_args(argv)
    findings = lint_paths(args.paths)
    if not args.quiet:
        for f in findings:
            print(f)
    n_files = sum(1 for _ in iter_py_files(args.paths))
    print(f"proxylint: {len(findings)} finding(s) in {n_files} file(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
