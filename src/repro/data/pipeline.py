"""Proxy-fed input pipeline (paper §3.5 async-resolve pattern + §5.6 style).

Producer subprocesses build batches, ``put`` them through a Store connector
(shm by default — zero-copy on-node), and enqueue tiny *proxies* on a
multiprocessing queue.  The consumer begins ``resolve_async`` on batch N+1
while step N computes, so host->store->host movement overlaps compute.

Straggler mitigation: each batch index can be produced by ``redundancy``
producers (first proxy wins; duplicates are evicted), and a consumer-side
deadline falls back to producing the batch inline — training never stalls on
a dead or slow producer.  Batches are deterministic by (seed, index), so
redundant/fallback production yields identical bytes.
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import queue as queue_mod
import threading
import time
from multiprocessing import connection as mp_connection
from typing import Any, Callable

from repro.core import Store, get_factory, resolve_async
from repro.core.proxy import Proxy, extract, is_resolved
from repro.core.serialize import materialize
from repro.core.store import StoreConfig, StoreFactory, get_or_create_store

# mp 'spawn' keeps producers free of the parent's JAX/XLA state
_CTX = mp.get_context("spawn")


def _producer_main(store_config_blob: bytes, make_batch_blob: bytes,
                   q, indices, redundancy_rank: int, delay_s: float) -> None:
    store_cfg: StoreConfig = pickle.loads(store_config_blob)
    make_batch: Callable[[int], Any] = pickle.loads(make_batch_blob)
    store = get_or_create_store(store_cfg)
    for idx in indices:
        if delay_s:
            time.sleep(delay_s)  # straggler injection (tests/benchmarks)
        batch = make_batch(idx)
        proxy = store.proxy(batch)
        q.put((idx, redundancy_rank, pickle.dumps(proxy)))


class ProxyDataPipeline:
    """Iterator of resolved batches with prefetch-by-proxy."""

    def __init__(self, store: Store, make_batch: Callable[[int], Any], *,
                 n_producers: int = 2, redundancy: int = 1,
                 prefetch: int = 2, deadline_s: float = 30.0,
                 straggler_delay_s: float = 0.0,
                 start_index: int = 0) -> None:
        self.store = store
        self.make_batch = make_batch
        self.deadline_s = deadline_s
        self.prefetch = prefetch
        self.next_index = start_index
        # ONE bounded queue PER producer (backpressure: at most ~prefetch
        # batches in flight each).  Per-producer queues are the crash
        # isolation the redundancy guarantee rests on: a producer killed
        # mid-enqueue can leave its own queue's shared write-lock held
        # forever, and with a single shared queue that deadlock would take
        # every *surviving* producer down with it — exactly the straggler
        # scenario redundancy exists to absorb.
        self._queues: list = []
        self._pending: dict[int, Proxy] = {}
        self._fallbacks = 0
        self._duplicates = 0
        self._procs: list[mp.Process] = []

        cfg_blob = pickle.dumps(store.config())
        fn_blob = pickle.dumps(make_batch)
        # round-robin index assignment x redundancy
        horizon = 1 << 16
        for r in range(redundancy):
            for w in range(n_producers):
                idxs = list(range(start_index + w, horizon, n_producers))
                delay = straggler_delay_s if (r == 0 and w == 0 and
                                              straggler_delay_s) else 0.0
                q = _CTX.Queue(maxsize=max(prefetch, 1) + 1)
                p = _CTX.Process(
                    target=_producer_main,
                    args=(cfg_blob, fn_blob, q, idxs, r, delay),
                    daemon=True)
                p.start()
                self._queues.append(q)
                self._procs.append(p)

    # ------------------------------------------------------------------
    def _take_one(self, timeout: float | None) -> tuple | None:
        """Pull one (idx, rank, blob) across the producer queues: non-
        blocking round-robin sweeps, then a blocking multi-pipe wait on
        every queue's reader until data or the deadline.  A queue whose
        producer died mid-write may yield garbage — it is skipped, never
        trusted to block."""
        # monotonic: a wall-clock (NTP) step must neither stall the drain
        # nor truncate it to an instant-empty poll.  None means block
        # until data (the Queue.get(timeout=None) contract this replaced).
        deadline = time.monotonic() + \
            (float("inf") if timeout is None else timeout)
        while True:
            for q in self._queues:
                try:
                    return q.get_nowait()
                except queue_mod.Empty:
                    continue
                except (EOFError, OSError, pickle.UnpicklingError):
                    continue     # crashed producer's queue: ignore
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            try:
                # kernel-blocking wait across every producer pipe: wakes
                # exactly on data (the parent's writer ends keep the pipes
                # from spurious EOF-readiness)
                mp_connection.wait(
                    [q._reader for q in self._queues],
                    timeout=None if remaining == float("inf")
                    else remaining)
            except OSError:      # a torn-down queue: fall back to a nap
                time.sleep(min(remaining, 0.005))

    def _drain(self, timeout: float | None) -> None:
        item = self._take_one(timeout)
        if item is None:
            return
        idx, rank, blob = item
        proxy = pickle.loads(blob)
        if idx in self._pending or idx < self.next_index:
            self._duplicates += 1
            self.store.evict(get_factory(proxy).key)  # redundant copy
        else:
            self._pending[idx] = proxy
            resolve_async(proxy)  # overlap: fetch while compute runs

    def __iter__(self):
        return self

    def __next__(self) -> Any:
        idx = self.next_index
        deadline = time.monotonic() + self.deadline_s
        while idx not in self._pending:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._fallbacks += 1  # straggler: produce inline
                self._pending[idx] = Proxy(lambda i=idx: self.make_batch(i))
                break
            self._drain(timeout=min(remaining, 0.25))
        # opportunistically pull prefetch proxies that already arrived
        for _ in range(self.prefetch):
            self._drain(timeout=0)
        proxy = self._pending.pop(idx)
        self.next_index = idx + 1
        batch = extract(proxy)
        factory = get_factory(proxy)
        if isinstance(factory, StoreFactory):  # consumed once -> evict
            if getattr(self.store.connector, "borrows_get", False):
                # shm-arena gets are views the producer recycles post-
                # evict: detach the batch before dropping the key, or the
                # next produced batch could overwrite this one mid-step
                batch = materialize(batch)
            self.store.evict(factory.key)
        return batch

    @property
    def stats(self) -> dict:
        return {"fallbacks": self._fallbacks, "duplicates": self._duplicates,
                "pending": len(self._pending), "next": self.next_index}

    def close(self) -> None:
        for p in self._procs:
            p.terminate()
        for p in self._procs:
            p.join(timeout=2)
