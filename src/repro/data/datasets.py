"""Deterministic synthetic token datasets (seeded; reproducible across
producers and restarts — a restarted trainer regenerates identical batches).
"""
from __future__ import annotations

import numpy as np


def lm_batch(seed: int, index: int, batch: int, seq: int, vocab: int,
             extras: dict | None = None) -> dict:
    """Batch ``index`` of a virtual infinite corpus.

    Markov-ish synthetic text: next token depends on the previous one plus
    seeded noise, so models can actually reduce loss on it (used by the e2e
    training example to show learning).
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, index]))
    base = rng.integers(0, vocab, size=(batch, 1), dtype=np.int32)
    steps = rng.integers(0, 17, size=(batch, seq), dtype=np.int32)
    toks = (np.cumsum(steps, axis=1, dtype=np.int64) + base) % vocab
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = tokens[:, 0]
    out = {"tokens": tokens, "labels": labels}
    if extras:
        for name, (shape, dtype) in extras.items():
            out[name] = rng.standard_normal((batch, *shape)).astype(dtype) * 0.02
    return out


def extras_for(cfg) -> dict:
    if cfg.family == "vlm":
        return {"vision_emb": ((cfg.n_img_tokens, cfg.d_model), np.float32)}
    if cfg.family == "audio":
        return {"frames": ((cfg.enc_frames, cfg.d_model), np.float32)}
    return {}
