"""AdamW + schedules, implemented directly in JAX (no optax dependency).

``moment_dtype`` is the 405B-on-one-pod knob (EXPERIMENTS.md §Dry-run):
bf16 first/second moments shrink optimizer state 2x; fp32 remains the
default.  Global-norm clipping and decoupled weight decay included.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16


def lr_at(step, cfg: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.peak_lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _decayable(path) -> bool:
    """No weight decay on norms/biases/scalars (standard practice)."""
    name = str(path[-1]) if path else ""
    return not any(t in name for t in ("ln", "norm", "bias", "b'", "a_log",
                                       "dt_bias", "d_skip", "pos"))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = lr_at(step, cfg)
    mdt = jnp.dtype(cfg.moment_dtype)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (mu32 / b1c) / (jnp.sqrt(nu32 / b2c) + cfg.eps)
        if cfg.weight_decay and _decayable(path):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    g_l = jax.tree.leaves(grads)
    mu_l = jax.tree.leaves(opt_state["mu"])
    nu_l = jax.tree.leaves(opt_state["nu"])
    out = [upd(path, p, g, mu, nu)
           for (path, p), g, mu, nu in zip(flat, g_l, mu_l, nu_l)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, stats
