"""Proxy-based checkpointing — the paper's model applied to training state.

A checkpoint is a *manifest of proxies*: every leaf (or leaf chunk) of the
train-state pytree is ``put`` through a Store and represented by a lazy
transparent proxy.  Because proxies are self-contained (factory embeds the
store config), the manifest is tiny, travels anywhere, and each consumer
resolves ONLY what it needs:

* a restoring host materializes just its shards (lazy restore),
* a different mesh can restore the same manifest (elastic resharding) —
  proxies are location- and layout-transparent,
* an inspection tool can look at one tensor without touching the rest.

Write path is crash-safe: data puts complete first, then the manifest, then
the ``latest`` pointer (atomic rename).  ``save_async`` overlaps serialization
with the next training step (the paper's §3.5 async pattern, producer side).
``keep_last`` garbage-collects via connector evictions.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.core import Store, serialize, deserialize
from repro.core.proxy import Proxy, get_factory, is_proxy


class ProxyCheckpointManager:
    def __init__(self, store: Store, directory: str, *, keep_last: int = 3,
                 chunk_bytes: int = 256 << 20) -> None:
        self.store = store
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.chunk_bytes = chunk_bytes
        self._save_thread: threading.Thread | None = None
        self._last_error: Exception | None = None

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def _leaf_to_proxies(self, leaf) -> dict:
        """One leaf -> proxy or list of chunk proxies (nested-proxy pattern)."""
        arr = np.asarray(leaf)
        if arr.nbytes <= self.chunk_bytes or arr.ndim == 0:
            return {"kind": "whole", "proxy": self.store.proxy(arr)}
        n_chunks = -(-arr.nbytes // self.chunk_bytes)
        chunks = np.array_split(arr, min(n_chunks, arr.shape[0]), axis=0)
        return {"kind": "chunked",
                "proxies": self.store.proxy_batch(list(chunks))}

    def save(self, step: int, state: Any, *, blocking: bool = True) -> None:
        if blocking:
            self._do_save(step, state)
        else:
            self.wait()  # one in-flight async save at a time
            # snapshot to host first so training can donate/overwrite buffers
            host_state = jax.tree.map(lambda a: np.asarray(a).copy(), state)
            self._save_thread = threading.Thread(
                target=self._guarded_save, args=(step, host_state),
                daemon=True)
            self._save_thread.start()

    save_async = lambda self, step, state: self.save(step, state,
                                                     blocking=False)

    def _guarded_save(self, step, state):
        try:
            self._do_save(step, state)
        except Exception as e:  # noqa: BLE001
            self._last_error = e

    def _do_save(self, step: int, state: Any) -> None:
        t0 = time.perf_counter()
        leaves, treedef = jax.tree_util.tree_flatten(state)
        entries = [self._leaf_to_proxies(leaf) for leaf in leaves]
        manifest = {
            "step": int(step),
            "treedef": jax.tree_util.tree_structure(state),
            "entries": entries,
            "ts": time.time(),  # lint: wallclock-ok (manifest timestamp)
            "save_s": None,
        }
        manifest["save_s"] = round(time.perf_counter() - t0, 3)
        tmp = self.dir / f".ckpt_{step:08d}.tmp"
        with open(tmp, "wb") as f:
            for seg in serialize(manifest):
                f.write(seg)
        tmp.replace(self.dir / f"ckpt_{step:08d}.manifest")
        latest = self.dir / ".latest.tmp"
        latest.write_text(json.dumps({"step": int(step)}))
        latest.replace(self.dir / "latest.json")
        self._gc()

    def wait(self) -> None:
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(f.stem.split("_")[1])
                      for f in self.dir.glob("ckpt_*.manifest"))

    def latest_step(self) -> int | None:
        p = self.dir / "latest.json"
        if not p.exists():
            return None
        step = json.loads(p.read_text())["step"]
        return step if (self.dir / f"ckpt_{step:08d}.manifest").exists() \
            else (self.steps() or [None])[-1]

    def _manifest(self, step: int) -> dict:
        blob = (self.dir / f"ckpt_{step:08d}.manifest").read_bytes()
        return deserialize(blob)

    def restore(self, step: int | None = None, *,
                leaf_filter=None, like: Any | None = None) -> Any:
        """Materialize a checkpoint.

        ``leaf_filter(index) -> bool`` restores a subset (hosts resolve only
        their shards); skipped leaves come back as unresolved proxies.
        ``like`` (a matching abstract/concrete pytree) re-casts dtypes and
        validates shapes after elastic resharding.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        man = self._manifest(step)

        # one batched resolve for every selected leaf (whole + chunk
        # proxies alike): grouped by store into a single get_batch per
        # store instead of one round trip per leaf
        from repro.core.proxy import extract
        from repro.core.store import resolve_async

        wanted: list = []
        for i, entry in enumerate(man["entries"]):
            if leaf_filter is not None and not leaf_filter(i):
                continue
            wanted.extend([entry["proxy"]] if entry["kind"] == "whole"
                          else entry["proxies"])
        if wanted:
            resolve_async(wanted)

        def materialize(i, entry):
            if leaf_filter is not None and not leaf_filter(i):
                return entry["proxy"] if entry["kind"] == "whole" \
                    else entry["proxies"]
            if entry["kind"] == "whole":
                return extract(entry["proxy"])
            return np.concatenate([np.asarray(p) for p in entry["proxies"]],
                                  axis=0)

        leaves = [materialize(i, e) for i, e in enumerate(man["entries"])]
        state = jax.tree_util.tree_unflatten(man["treedef"], leaves)
        if like is not None:
            state = jax.tree.map(
                lambda ref, got: np.asarray(got).astype(ref.dtype), like,
                state)
        return state

    def restore_step_count(self) -> int | None:
        s = self.latest_step()
        return None if s is None else self._manifest(s)["step"]

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            try:
                man = self._manifest(s)
                for e in man["entries"]:
                    proxies = [e["proxy"]] if e["kind"] == "whole" \
                        else e["proxies"]
                    for p in proxies:
                        self.store.evict(get_factory(p).key)
            except Exception:  # noqa: BLE001 - GC best-effort
                pass
            (self.dir / f"ckpt_{s:08d}.manifest").unlink(missing_ok=True)
