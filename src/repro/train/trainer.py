"""Restartable trainer: proxy-fed inputs, async proxy checkpoints, resume.

Fault-tolerance contract (tested in tests/test_fault_tolerance.py):
* checkpoint every ``ckpt_every`` steps via ProxyCheckpointManager
  (async — overlaps the next step),
* on restart, resume from the newest complete manifest; the data pipeline
  is deterministic by (seed, batch index), so the token stream continues
  exactly where the failed run left off,
* a mid-step crash loses at most ``ckpt_every`` steps of work.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import Store
from repro.core.connectors import SharedMemoryConnector
from repro.data.datasets import extras_for, lm_batch
from repro.data.pipeline import ProxyDataPipeline
from repro.train.checkpoints import ProxyCheckpointManager
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 4
    seq: int = 128
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 25
    keep_last: int = 3
    n_producers: int = 2
    redundancy: int = 1
    workdir: str = "/tmp/repro_train"
    resume: bool = True
    crash_at_step: int | None = None   # fault-injection (tests)


def _make_batch_fn(cfg: ArchConfig, tc: TrainConfig) -> Callable[[int], Any]:
    return partial(lm_batch, tc.seed, batch=tc.batch, seq=tc.seq,
                   vocab=cfg.vocab, extras=extras_for(cfg))


class Trainer:
    def __init__(self, cfg: ArchConfig, tc: TrainConfig,
                 opt_cfg: OptConfig | None = None,
                 store: Store | None = None) -> None:
        self.cfg, self.tc = cfg, tc
        self.opt_cfg = opt_cfg or OptConfig(warmup_steps=10,
                                            decay_steps=max(tc.steps, 2))
        wd = Path(tc.workdir)
        wd.mkdir(parents=True, exist_ok=True)
        self.store = store or Store(
            f"trainer-{wd.name}", SharedMemoryConnector(str(wd / "shm")))
        self.ckpts = ProxyCheckpointManager(self.store, str(wd / "ckpts"),
                                            keep_last=tc.keep_last)
        self.step_fn = jax.jit(make_train_step(cfg, self.opt_cfg),
                               donate_argnums=(0,))
        self.history: list[dict] = []

    def _init_or_resume(self):
        start = 0
        if self.tc.resume and self.ckpts.latest_step() is not None:
            like = jax.eval_shape(lambda: init_train_state(
                jax.random.key(self.tc.seed), self.cfg, self.opt_cfg))
            state = self.ckpts.restore(like=like)
            state = jax.tree.map(jax.numpy.asarray, state)
            start = int(np.asarray(state["opt"]["step"]))
            print(f"[trainer] resumed from step {start}", flush=True)
        else:
            state = init_train_state(jax.random.key(self.tc.seed), self.cfg,
                                     self.opt_cfg)
        return state, start

    def run(self) -> dict:
        tc = self.tc
        state, start = self._init_or_resume()
        pipe = ProxyDataPipeline(
            self.store, _make_batch_fn(self.cfg, tc),
            n_producers=tc.n_producers, redundancy=tc.redundancy,
            start_index=start)
        t0 = time.perf_counter()
        try:
            for step in range(start, tc.steps):
                if tc.crash_at_step is not None and step == tc.crash_at_step:
                    raise RuntimeError(f"injected crash at step {step}")
                batch = next(pipe)
                state, metrics = self.step_fn(state, batch)
                if (step + 1) % tc.log_every == 0 or step + 1 == tc.steps:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m["step"] = step + 1
                    m["s_per_step"] = \
                        (time.perf_counter() - t0) / (step + 1 - start)
                    self.history.append(m)
                    print(f"[trainer] step {step+1}/{tc.steps} "
                          f"loss={m['loss']:.4f} "
                          f"({m['s_per_step']:.2f}s/step)", flush=True)
                if (step + 1) % tc.ckpt_every == 0:
                    self.ckpts.save_async(step + 1, state)
            self.ckpts.wait()
            self.ckpts.save(tc.steps, state)
            return {"final_loss": self.history[-1]["loss"] if self.history
                    else None, "history": self.history,
                    "pipeline": pipe.stats}
        finally:
            pipe.close()
            try:  # crash path: flush any in-flight async checkpoint so the
                # restart point is the newest COMPLETE manifest
                self.ckpts.wait()
            except Exception:  # noqa: BLE001 - best-effort on teardown
                pass
