"""Train-state + step factories (the functions the dry-run lowers)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import build_model
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def init_train_state(key, cfg, opt_cfg: OptConfig):
    model = build_model(cfg)
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def abstract_train_state(cfg, opt_cfg: OptConfig):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, opt_cfg))


def make_train_step(cfg, opt_cfg: OptConfig, *,
                    n_microbatches: int = 1) -> Callable:
    """Standard step, or gradient-accumulation over ``n_microbatches``
    (scan over batch slices; peak activation memory scales ~1/n at the cost
    of n sequential passes — a §Perf memory lever for the 405B cell)."""
    model = build_model(cfg)
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(state, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(state["params"], batch)
        else:
            def split(a):
                b = a.shape[0]
                assert b % n_microbatches == 0, (b, n_microbatches)
                mb = b // n_microbatches
                return jnp.moveaxis(
                    a.reshape(n_microbatches, mb, *a.shape[1:]), 0, 0)

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def acc_body(carry, mb):
                grads_acc, loss_acc, metrics_acc = carry
                (loss, metrics), grads = grad_fn(state["params"], mb)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
                metrics_acc = {k: metrics_acc.get(k, 0.0) + v
                               for k, v in metrics.items()}
                return (grads_acc, loss_acc + loss, metrics_acc), None

            metrics0 = {k: jnp.zeros((), jnp.float32)
                        for k in (["nll", "lb_loss", "z_loss"]
                                  if cfg.family == "moe" else ["nll"])}
            (grads, loss, metrics), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32), metrics0),
                micro)
            inv = 1.0 / n_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = {k: v * inv for k, v in metrics.items()}
        new_params, new_opt, stats = adamw_update(
            grads, state["opt"], state["params"], opt_cfg)
        metrics = dict(metrics, loss=loss, **stats)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg) -> Callable:
    model = build_model(cfg)

    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return dict(metrics, loss=loss)

    return eval_step


def make_prefill_step(cfg) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(cfg) -> Callable:
    model = build_model(cfg)

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return logits, cache

    return serve_step
