"""Shared neural-net layers (pure JAX, functional).

Conventions:
* params are plain dict pytrees; per-layer tensors carry a leading ``L`` dim
  when the stack is scanned,
* activations flow in ``cfg.dtype`` (bf16); softmax/norm accumulate in fp32,
* ``shard_as(x, *logical_dims)`` applies the active logical sharding rules
  (no-op outside a rules context) — model code never names mesh axes.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_as


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms / embeddings / positional
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def init_rms(key, dim):
    # stored as delta from 1 (so zeros-init == identity scale)
    return jnp.zeros((dim,), jnp.float32)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, n, HD); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; chunked reference / full / pallas)
# ---------------------------------------------------------------------------
def _attn_chunk_body(q_chunk, k, v, *, q_start, causal, window, scale):
    """One query chunk vs the full K/V. q_chunk: (B, Cq, H, HD).

    The 'attn_q' rule (when set to 'model') pins the big score tensor to
    query-position sharding: with GQA kv_heads < mesh axis, head sharding
    can't cover the axis and GSPMD otherwise picks mismatched intermediate
    shardings and reshards the O(S^2) scores per layer (§Perf B3 — measured
    at 100-300 s of ICI time per step before this constraint).
    """
    b, cq, h, hd = q_chunk.shape
    kv = k.shape[2]
    g = h // kv
    qg = q_chunk.reshape(b, cq, kv, g, hd)
    qg = shard_as(qg, "batch", "attn_q", "kv_heads", None, None)
    # scores: (B, KV, G, Cq, Skv)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = shard_as(s, "batch", "kv_heads", None, "attn_q", None)
    skv = k.shape[1]
    q_pos = q_start + jnp.arange(cq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((cq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q_chunk.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    o = shard_as(o, "batch", "attn_q", "kv_heads", None, None)
    return o.reshape(b, cq, h, hd)


def attention(q, k, v, cfg, *, causal=True, q_offset=0):
    """q: (B, Sq, H, HD); k, v: (B, Skv, KV, HD) -> (B, Sq, H, HD).

    ``chunked``: lax.scan over query chunks with an inner remat so the O(S^2)
    score tensor never exceeds one chunk — the XLA-path analog of the Pallas
    flash kernel (which replaces this on TPU via cfg.attention_impl).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    window = cfg.sliding_window
    if cfg.attention_impl == "ablate":
        # HLO-ablation stand-in (perf accounting): keeps shapes/graph around
        # the attention site while removing its FLOPs/bytes, so lowering the
        # same program with/without measures attention's exact contribution.
        b, sq, h, hd = q.shape
        # keep q/k/v live (cheap reductions) so XLA cannot dead-code the
        # projections and over-attribute bytes to attention
        stub = jnp.mean(k, axis=(1, 2)) + jnp.mean(v, axis=(1, 2))  # (B, HD)
        return q * scale + stub[:, None, None, :].astype(q.dtype)
    if cfg.attention_impl == "pallas":
        from repro.kernels.ops import flash_attention as _fa

        return _fa(q, k, v, causal=causal, window=window, q_offset=q_offset)
    nq = q.shape[1]
    chunk = min(cfg.attn_chunk, nq)
    if cfg.attention_impl == "full" or nq <= chunk or nq % chunk != 0:
        return _attn_chunk_body(q, k, v, q_start=q_offset, causal=causal,
                                window=window, scale=scale)

    n_chunks = nq // chunk
    qs = q.reshape(q.shape[0], n_chunks, chunk, *q.shape[2:])

    if cfg.attn_unroll:  # cost-variant: identical math, no while loop, so
        # XLA cost analysis sees every chunk (see launch/dryrun.py)
        outs = [_attn_chunk_body(qs[:, i], k, v,
                                 q_start=q_offset + i * chunk,
                                 causal=causal, window=window, scale=scale)
                for i in range(n_chunks)]
        return jnp.concatenate(outs, axis=1)

    @jax.checkpoint
    def body(_, qc_i):
        qc, i = qc_i
        o = _attn_chunk_body(qc, k, v, q_start=q_offset + i * chunk,
                             causal=causal, window=window, scale=scale)
        return None, o

    _, out = jax.lax.scan(
        body, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(n_chunks)))
    return jnp.moveaxis(out, 0, 1).reshape(q.shape)


def decode_attention(q, k_cache, v_cache, length, cfg):
    """Single-position attention against a (possibly ring) KV cache.

    q: (B, 1, H, HD); caches: (B, S_cache, KV, HD); ``length`` = number of
    valid entries — a scalar (lockstep batch) or a ``(B,)`` vector
    (continuous batching: each row's cache is left-aligned and valid up to
    its own length).  Softmax in fp32; masked beyond ``length``.
    """
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    length = jnp.asarray(length)
    if length.ndim == 0:
        valid = jnp.arange(k_cache.shape[1]) < length          # (S,)
        s = jnp.where(valid[None, None, None], s, -1e30)
    else:
        valid = jnp.arange(k_cache.shape[1])[None, :] < length[:, None]
        s = jnp.where(valid[:, None, None, :], s, -1e30)       # (B, S)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache)
    return o.reshape(b, 1, h, hd)


def init_attn(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    dt = dtype_of(cfg)
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dt) * std,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dt) * std,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dt) * std,
        "wo": jax.random.normal(ks[3], (h * hd, d), dt) * std / math.sqrt(2 * cfg.n_layers),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def attn_qkv(p, x, cfg, positions):
    """Project + RoPE. x: (B, S, D) -> q (B,S,H,HD), k/v (B,S,KV,HD)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard_as(q.reshape(b, s, h, hd), "batch", "seq", "heads", None)
    k = shard_as(k.reshape(b, s, kv, hd), "batch", "seq", "kv_heads", None)
    v = shard_as(v.reshape(b, s, kv, hd), "batch", "seq", "kv_heads", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(p, x, cfg, *, positions, causal=True, memory=None):
    """Full attention sublayer (self or cross). x: (B, S, D)."""
    if memory is None:
        q, k, v = attn_qkv(p, x, cfg, positions)
    else:  # cross-attention: keys/values from encoder memory (no RoPE)
        b, s, _ = x.shape
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = (x @ p["wq"]).reshape(b, s, h, hd)
        k = (memory @ p["wk"]).reshape(b, memory.shape[1], kv, hd)
        v = (memory @ p["wv"]).reshape(b, memory.shape[1], kv, hd)
        causal = False
    o = attention(q, k, v, cfg, causal=causal)
    o = o.reshape(*x.shape[:2], -1)
    return shard_as(o @ p["wo"], "batch", "act_seq", "embed")


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    std = 1.0 / math.sqrt(d)
    dt = dtype_of(cfg)
    p = {
        "w_up": jax.random.normal(ks[0], (d, f), dt) * std,
        "w_down": jax.random.normal(ks[1], (f, d), dt) * (std / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.act == "silu":
        p["w_gate"] = jax.random.normal(ks[2], (d, f), dt) * std
    return p


def mlp_block(p, x, cfg):
    h = x @ p["w_up"]
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = shard_as(h, "batch", "seq", "ff")
    return shard_as(h @ p["w_down"], "batch", "act_seq", "embed")


# ---------------------------------------------------------------------------
# embeddings + chunked loss
# ---------------------------------------------------------------------------
def init_embeddings(key, cfg):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 2)
    v = cfg.padded_vocab
    p = {"emb": jax.random.normal(ks[0], (v, cfg.d_model), dt) * 0.02,
         "ln_f": init_rms(ks[1], cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unemb"] = jax.random.normal(ks[1], (cfg.d_model, v), dt) * 0.02
    return p


def unembed(p, x, cfg):
    w = p["emb"].T if cfg.tie_embeddings else p["unemb"]
    return shard_as(x @ w, "batch", "seq", "vocab")


def chunked_xent(p, x, labels, cfg, weights=None):
    """Sequence-chunked softmax cross-entropy; never materializes full logits.

    x: (B, S, D), labels: (B, S), weights: optional (B, S) or (1, S)
    -> scalar mean nll (fp32) over weighted positions.
    """
    b, s, d = x.shape
    chunk = min(cfg.loss_chunk, s)
    if s % chunk != 0:
        chunk = s
    n = s // chunk
    w = p["emb"].T if cfg.tie_embeddings else p["unemb"]
    if weights is None:
        weights = jnp.ones((1, s), jnp.float32)
    weights = jnp.broadcast_to(weights, (b, s))

    @jax.checkpoint
    def body(acc, xlw):
        xc, lc, wc = xlw  # (B, chunk, D), (B, chunk), (B, chunk)
        logits = shard_as((xc @ w).astype(jnp.float32), "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction (not take_along_axis): partitions cleanly when
        # the vocab dim is sharded -> partial sums + one small all-reduce
        onehot = jax.nn.one_hot(lc, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return acc + jnp.sum((logz - gold) * wc), None

    def chunks(a):
        return jnp.moveaxis(a.reshape(b, n, chunk, *a.shape[2:]), 1, 0)

    xs = (chunks(x), chunks(labels), chunks(weights))
    if cfg.loss_unroll:  # cost-variant path (see attention above)
        total = jnp.zeros((), jnp.float32)
        for i in range(n):
            total, _ = body(total, jax.tree.map(lambda a: a[i], xs))
    else:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(jnp.sum(weights), 1.0)
