"""Model assembly for all assigned families.

``build_model(cfg)`` returns a :class:`Model` namespace:

* ``init(key) -> params``                    (materializes; smoke/reduced only)
* ``loss(params, batch) -> (loss, metrics)`` (train shapes)
* ``prefill(params, batch) -> (last_logits, cache)``
* ``decode_step(params, cache, token, pos) -> (logits, cache)``

Layer stacks are ``lax.scan`` over stacked params (cfg.scan_layers) with
per-layer remat — mandatory for the 126-layer/405B dry-run; the hybrid decode
path is a Python loop (38 small layers, shared attention needs per-site KV).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_as
from repro.models import layers as L
from repro.models.moe import init_moe, moe_block, moe_decode
from repro.models.ssm import (init_ssm, init_ssm_cache, ssm_block,
                              ssm_decode_block)


@dataclass
class Model:
    cfg: Any
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _stacked(init_one, key, n):
    """vmap an init over layer indices -> stacked (L, ...) params."""
    return jax.vmap(init_one)(jax.random.split(key, n))


def _scan_layers(body, x, stacked_params, cfg, extra=None):
    """Scan ``body(x, layer_params, extra) -> x`` over the layer stack.

    cfg.scan_group > 0 enables sqrt-remat: an outer scan over G groups whose
    body (an inner scan over L/G layers) is itself rematerialized — the
    bwd-saved residual stack shrinks from L x |x| to (G + L/G) x |x|
    (classic sqrt(L) checkpointing; the 405B memory lever in §Perf).
    """
    fn = _maybe_remat(lambda carry, p: (body(carry, p, extra), None), cfg)
    if cfg.scan_layers and cfg.scan_group > 1:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        g = cfg.scan_group
        assert n % g == 0, (n, g)
        grouped = jax.tree.map(
            lambda a: a.reshape(g, n // g, *a.shape[1:]), stacked_params)

        @jax.checkpoint
        def group_body(carry, group_params):
            carry, _ = jax.lax.scan(fn, carry, group_params)
            return carry, None

        x, _ = jax.lax.scan(group_body, x, grouped)
        return x
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, stacked_params)
        return x
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    for i in range(n):
        x, _ = fn(x, jax.tree.map(lambda a: a[i], stacked_params))
    return x


# ---------------------------------------------------------------------------
# decoder blocks (dense / moe)
# ---------------------------------------------------------------------------
def _init_dense_layer(cfg):
    def one(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"ln1": L.init_rms(k1, cfg.d_model),
                "attn": L.init_attn(k2, cfg),
                "ln2": L.init_rms(k3, cfg.d_model),
                "mlp": L.init_mlp(k4, cfg)}
    return one


def _init_moe_layer(cfg):
    def one(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"ln1": L.init_rms(k1, cfg.d_model),
                "attn": L.init_attn(k2, cfg),
                "ln2": L.init_rms(k3, cfg.d_model),
                "moe": init_moe(k4, cfg)}
    return one


def _dense_body(x, p, cfg, positions):
    x = x + L.attn_block(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                         cfg, positions=positions)
    x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return shard_as(x, "batch", "act_seq", "embed")


def _moe_body(carry, p, cfg, positions):
    x, aux = carry
    x = x + L.attn_block(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                         cfg, positions=positions)
    y, a = moe_block(p["moe"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    x = shard_as(x + y, "batch", "act_seq", "embed")
    return x, {k: aux[k] + a[k] for k in aux}


# ---------------------------------------------------------------------------
# forward passes (hidden states)
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg):
    x = params["tok"]["emb"][batch["tokens"]]
    if cfg.family == "vlm":
        # patch embeddings (stub frontend) occupy the first n_img positions
        x = jax.lax.dynamic_update_slice(
            x, batch["vision_emb"].astype(x.dtype), (0, 0, 0))
    return shard_as(x, "batch", "act_seq", "embed")


def _decoder_hidden(params, x, cfg, positions):
    """Dense/vlm/moe decoder stack -> (hidden, aux)."""
    if cfg.family == "moe":
        def body(carry, p, _):
            return _moe_body(carry, p, cfg, positions)
        aux0 = {"lb_loss": jnp.zeros((), jnp.float32),
                "z_loss": jnp.zeros((), jnp.float32)}
        x, aux = _scan_layers_carry(body, (x, aux0), params["layers"], cfg)
    else:
        def body(x, p, _):
            return _dense_body(x, p, cfg, positions)
        x = _scan_layers(body, x, params["layers"], cfg)
        aux = {}
    return L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps), aux


def _scan_layers_carry(body, carry, stacked_params, cfg):
    fn = _maybe_remat(lambda c, p: (body(c, p, None), None), cfg)
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(fn, carry, stacked_params)
        return carry
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    for i in range(n):
        carry, _ = fn(carry, jax.tree.map(lambda a: a[i], stacked_params))
    return carry


# ---------------------------------------------------------------------------
# ssm / hybrid stacks
# ---------------------------------------------------------------------------
def _ssm_body(x, p, cfg):
    x = x + ssm_block(p["ssm"], L.rms_norm(x, p["ln"], cfg.norm_eps), cfg)
    return shard_as(x, "batch", "act_seq", "embed")


def _shared_attn_block(shared, x, cfg, positions):
    x = x + L.attn_block(shared["attn"],
                         L.rms_norm(x, shared["ln1"], cfg.norm_eps),
                         cfg, positions=positions)
    x = x + L.mlp_block(shared["mlp"],
                        L.rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
    return x


def _hybrid_hidden(params, x, cfg, positions):
    shared = params["shared"]
    flags = (jnp.arange(cfg.n_layers) % cfg.attn_every) == 0

    def body(x, p_flag, _):
        p, flag = p_flag
        x = jax.lax.cond(
            flag,
            lambda x: _shared_attn_block(shared, x, cfg, positions),
            lambda x: x, x)
        return _ssm_body(x, p, cfg)

    x = _scan_layers(body, x, (params["layers"], flags), cfg)
    return L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps), {}


# ---------------------------------------------------------------------------
# whisper-style encoder-decoder
# ---------------------------------------------------------------------------
def _enc_body(x, p, cfg, positions):
    x = x + L.attn_block(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                         cfg, positions=positions, causal=False)
    x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return shard_as(x, "batch", "act_seq", "embed")


def _dec_body(x, p, cfg, positions, memory):
    x = x + L.attn_block(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                         cfg, positions=positions)
    x = x + L.attn_block(p["xattn"], L.rms_norm(x, p["lnx"], cfg.norm_eps),
                         cfg, positions=positions, memory=memory)
    x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return shard_as(x, "batch", "act_seq", "embed")


def _encode(params, frames, cfg):
    x = frames.astype(L.dtype_of(cfg)) + params["enc_pos"].astype(L.dtype_of(cfg))
    pos = jnp.arange(frames.shape[1])

    def body(x, p, _):
        return _enc_body(x, p, cfg, pos)

    x = _scan_layers(body, x, params["enc"], cfg)
    return L.rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {"tok": L.init_embeddings(ks[0], cfg)}
    if cfg.family in ("dense", "vlm"):
        params["layers"] = _stacked(_init_dense_layer(cfg), ks[1], cfg.n_layers)
    elif cfg.family == "moe":
        params["layers"] = _stacked(_init_moe_layer(cfg), ks[1], cfg.n_layers)
    elif cfg.family == "ssm":
        def one(key):
            k1, k2 = jax.random.split(key)
            return {"ln": L.init_rms(k1, cfg.d_model), "ssm": init_ssm(k2, cfg)}
        params["layers"] = _stacked(one, ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        def one(key):
            k1, k2 = jax.random.split(key)
            return {"ln": L.init_rms(k1, cfg.d_model), "ssm": init_ssm(k2, cfg)}
        params["layers"] = _stacked(one, ks[1], cfg.n_layers)
        k1, k2, k3, k4 = jax.random.split(ks[2], 4)
        params["shared"] = {"ln1": L.init_rms(k1, cfg.d_model),
                            "attn": L.init_attn(k2, cfg),
                            "ln2": L.init_rms(k3, cfg.d_model),
                            "mlp": L.init_mlp(k4, cfg)}
    elif cfg.family == "audio":
        def dec_one(key):
            k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
            return {"ln1": L.init_rms(k1, cfg.d_model),
                    "attn": L.init_attn(k2, cfg),
                    "lnx": L.init_rms(k3, cfg.d_model),
                    "xattn": L.init_attn(k4, cfg),
                    "ln2": L.init_rms(k5, cfg.d_model),
                    "mlp": L.init_mlp(k6, cfg)}
        params["layers"] = _stacked(dec_one, ks[1], cfg.n_layers)
        params["enc"] = _stacked(_init_dense_layer(cfg), ks[2], cfg.n_enc_layers)
        params["enc_pos"] = jax.random.normal(
            ks[3], (cfg.enc_frames, cfg.d_model), jnp.float32) * 0.02
        params["enc_ln_f"] = L.init_rms(ks[4], cfg.d_model)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# loss (train shapes)
# ---------------------------------------------------------------------------
def model_loss(params, batch, cfg):
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    if cfg.family == "audio":
        memory = _encode(params, batch["frames"], cfg)

        def body(x, p, _):
            return _dec_body(x, p, cfg, positions, memory)

        x = _embed_inputs(params, batch, cfg)
        x = _scan_layers(body, x, params["layers"], cfg)
        h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
        aux = {}
    elif cfg.family == "hybrid":
        x = _embed_inputs(params, batch, cfg)
        h, aux = _hybrid_hidden(params, x, cfg, positions)
    elif cfg.family == "ssm":
        x = _embed_inputs(params, batch, cfg)

        def body(x, p, _):
            return _ssm_body(x, p, cfg)

        x = _scan_layers(body, x, params["layers"], cfg)
        h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
        aux = {}
    else:
        x = _embed_inputs(params, batch, cfg)
        h, aux = _decoder_hidden(params, x, cfg, positions)

    weights = None
    if cfg.family == "vlm":  # no next-token loss on image positions
        weights = (positions >= cfg.n_img_tokens).astype(jnp.float32)[None, :]
    nll = L.chunked_xent(params["tok"], h, batch["labels"], cfg, weights=weights)
    metrics = {"nll": nll}
    loss = nll
    if aux:
        n_l = cfg.n_layers
        loss = loss + 0.01 * aux["lb_loss"] / n_l + 1e-3 * aux["z_loss"] / n_l
        metrics.update({k: v / n_l for k, v in aux.items()})
    return loss, metrics
