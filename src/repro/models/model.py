"""build_model(cfg) + abstract input specs for every (arch x shape) cell."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import serve_paths as S
from repro.models import transformer as T


def build_model(cfg: ArchConfig) -> T.Model:
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        prefill, decode = S.decoder_prefill, S.decoder_decode_step
    elif fam == "audio":
        prefill, decode = S.audio_prefill, S.audio_decode_step
    elif fam == "ssm":
        prefill, decode = S.ssm_prefill, S.ssm_decode_step
    elif fam == "hybrid":
        prefill, decode = S.hybrid_prefill, S.hybrid_decode_step
    else:
        raise ValueError(fam)
    return T.Model(
        cfg=cfg,
        init=lambda key: T.init_params(key, cfg),
        loss=lambda params, batch: T.model_loss(params, batch, cfg),
        prefill=lambda params, batch: prefill(params, batch, cfg),
        decode_step=lambda params, cache, token, pos: decode(
            params, cache, token, pos, cfg),
    )


# ---------------------------------------------------------------------------
# abstract specs (ShapeDtypeStruct stand-ins; no allocation) — dry-run inputs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                with_labels: bool = True) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        batch["vision_emb"] = _sds((b, cfg.n_img_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.enc_frames, cfg.d_model), dt)
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> Any:
    b, s = shape.global_batch, shape.seq_len
    lyr, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    w = min(s, cfg.sliding_window) if cfg.sliding_window else s

    def ssm_cache(lead):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": _sds((*lead, b, cfg.ssm_conv - 1, cd), dt),
                "state": _sds((*lead, b, cfg.n_ssm_heads, cfg.ssm_head_dim,
                               cfg.ssm_state), jnp.float32)}

    if cfg.family in ("dense", "vlm", "moe"):
        return {"k": _sds((lyr, b, w, kv, hd), dt),
                "v": _sds((lyr, b, w, kv, hd), dt)}
    if cfg.family == "audio":
        f = cfg.enc_frames
        return {"k": _sds((lyr, b, s, kv, hd), dt),
                "v": _sds((lyr, b, s, kv, hd), dt),
                "ck": _sds((lyr, b, f, kv, hd), dt),
                "cv": _sds((lyr, b, f, kv, hd), dt)}
    if cfg.family == "ssm":
        return ssm_cache((lyr,))
    if cfg.family == "hybrid":
        n_sites = len(S._attn_sites(cfg))
        return {"ssm": ssm_cache((lyr,)),
                "attn": {"k": _sds((n_sites, b, s, kv, hd), dt),
                         "v": _sds((n_sites, b, s, kv, hd), dt)}}
    raise ValueError(cfg.family)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract inputs for the step function this shape lowers.

    train  -> {"batch": ...}                           (train_step)
    prefill-> {"batch": ...} (no labels)               (prefill_step)
    decode -> {"cache", "token", "pos"}                (serve_step)
    """
    b = shape.global_batch
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    if shape.kind == "decode":
        return {"cache": cache_specs(cfg, shape),
                "token": _sds((b, 1), jnp.int32),
                "pos": _sds((), jnp.int32)}
    raise ValueError(shape.kind)


def abstract_params(cfg: ArchConfig) -> Any:
    """Parameter ShapeDtypeStructs without materializing (eval_shape)."""
    return jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
