"""Serving paths: prefill (build cache) + single-token decode, per family.

Cache layouts (leading L dim so layer scans carry them):
  attention: {"k","v": (L, B, S_c, KV, HD)}  S_c = sliding window if set
  audio:     + {"ck","cv": (L, B, F, KV, HD)} cross-attn KV (precomputed)
  ssm:       {"conv": (L, B, K-1, cd), "state": (L, B, H, P, N)}
  hybrid:    {"ssm": ..., "attn": {"k","v": (n_sites, B, S_c, H, HD)}}

Ring-buffer semantics for sliding windows: slot = pos % W; validity by
count, not order (softmax is order-invariant; RoPE is baked in at write).

:class:`KVBlockPool` (bottom of this module) is the serving engine's paged
KV storage: a request's prefilled/decoded KV lives in fixed-size *blocks*
backed by refcounted arena slots with TTL leases, so cache memory is
request-lifetime-managed by the same ownership machinery as every other
object on the proxy data plane.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_as
from repro.models import layers as L
from repro.models.moe import moe_block, moe_decode
from repro.models.ssm import (init_ssm_cache, ssm_decode_block,
                              ssm_prefill_block)
from repro.models.transformer import (_embed_inputs, _encode,
                                      _shared_attn_block, _maybe_remat)


def _layer_scan(body, carry, xs, cfg):
    """lax.scan over the layer stack, or an unrolled loop when
    cfg.scan_layers is False (the dry-run cost variant needs unrolled HLO
    because XLA cost analysis counts while-loop bodies once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        out = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        out = None
    return carry, out


def _cache_len(cfg, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def _shard_cache(t):
    return shard_as(t, "batch", "cache_seq", "kv_heads", "hd_tp")


# ---------------------------------------------------------------------------
# dense / moe / vlm decoder
# ---------------------------------------------------------------------------
def decoder_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    positions = jnp.arange(s)
    w = _cache_len(cfg, s)
    x = _embed_inputs(params, batch, cfg)

    def body(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        o = L.attention(q, k, v, cfg, causal=True)
        x = x + shard_as(o.reshape(bsz, s, -1) @ p["attn"]["wo"],
                         "batch", "act_seq", "embed")
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(p["moe"], h2, cfg)
        else:
            y = L.mlp_block(p["mlp"], h2, cfg)
        x = shard_as(x + y, "batch", "act_seq", "embed")
        kc = _shard_cache(k[:, s - w:])
        vc = _shard_cache(v[:, s - w:])
        return x, (kc, vc)

    x, (ks, vs) = _layer_scan(_maybe_remat(body, cfg), x, params["layers"], cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


def decoder_decode_step(params, cache, token, pos, cfg):
    """token: (B, 1) int32; pos: next-position index — a scalar int32
    (lockstep batch: every row at the same position) or a ``(B,)`` vector
    (continuous batching: per-row positions; each row's KV is left-aligned
    in its cache row and the new entry scatters to ``pos[b]``)."""
    bsz = token.shape[0]
    x = params["tok"]["emb"][token]
    pos = jnp.asarray(pos)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else pos[None]
    w = cache["k"].shape[2]
    slot = pos % w if cfg.sliding_window else pos
    length = jnp.minimum(pos + 1, w)
    rows = jnp.arange(bsz)

    def body(x, p_kv):
        p, kc, vc = p_kv
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        if per_row:
            kc = kc.at[rows, slot].set(k[:, 0])
            vc = vc.at[rows, slot].set(v[:, 0])
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = L.decode_attention(q, kc, vc, length, cfg)
        x = x + o.reshape(bsz, 1, -1) @ p["attn"]["wo"]
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_decode(p["moe"], h2, cfg)
        else:
            y = L.mlp_block(p["mlp"], h2, cfg)
        return x + y, (kc, vc)

    x, (ks, vs) = _layer_scan(body, x, (params["layers"], cache["k"],
                                        cache["v"]), cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# whisper-style enc-dec
# ---------------------------------------------------------------------------
def audio_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    memory = _encode(params, batch["frames"], cfg)
    positions = jnp.arange(s)
    x = params["tok"]["emb"][tokens]
    f = memory.shape[1]
    kv, hd = cfg.n_kv_heads, cfg.hd

    def body(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        o = L.attention(q, k, v, cfg, causal=True)
        x = x + o.reshape(bsz, s, -1) @ p["attn"]["wo"]
        hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        ck = (memory @ p["xattn"]["wk"]).reshape(bsz, f, kv, hd)
        cv = (memory @ p["xattn"]["wv"]).reshape(bsz, f, kv, hd)
        qx = (hx @ p["xattn"]["wq"]).reshape(bsz, s, cfg.n_heads, hd)
        ox = L.attention(qx, ck, cv, cfg, causal=False)
        x = x + ox.reshape(bsz, s, -1) @ p["xattn"]["wo"]
        x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = _layer_scan(_maybe_remat(body, cfg), x,
                                        params["layers"], cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs, "ck": cks, "cv": cvs}


def audio_decode_step(params, cache, token, pos, cfg):
    bsz = token.shape[0]
    x = params["tok"]["emb"][token]
    positions = pos[None]
    f = cache["ck"].shape[2]
    length = pos + 1

    def body(x, p_kv):
        p, kc, vc, ck, cv = p_kv
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        o = L.decode_attention(q, kc, vc, length, cfg)
        x = x + o.reshape(bsz, 1, -1) @ p["attn"]["wo"]
        hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"]).reshape(bsz, 1, cfg.n_heads, cfg.hd)
        ox = L.decode_attention(qx, ck, cv, f, cfg)
        x = x + ox.reshape(bsz, 1, -1) @ p["xattn"]["wo"]
        x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x, (kc, vc)

    x, (ks, vs) = _layer_scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["ck"],
                  cache["cv"]), cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs, "ck": cache["ck"],
                                        "cv": cache["cv"]}


# ---------------------------------------------------------------------------
# ssm
# ---------------------------------------------------------------------------
def ssm_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    x = _embed_inputs(params, batch, cfg)

    def body(x, p):
        y, c = ssm_prefill_block(p["ssm"],
                                 L.rms_norm(x, p["ln"], cfg.norm_eps), cfg)
        return shard_as(x + y, "batch", "act_seq", "embed"), c

    x, cache = _layer_scan(_maybe_remat(body, cfg), x, params["layers"], cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    return logits.astype(jnp.float32), cache


def ssm_decode_step(params, cache, token, pos, cfg):
    x = params["tok"]["emb"][token]

    def body(x, p_c):
        p, conv, state = p_c
        y, c = ssm_decode_block(p["ssm"],
                                L.rms_norm(x, p["ln"], cfg.norm_eps),
                                {"conv": conv, "state": state}, cfg)
        return x + y, (c["conv"], c["state"])

    x, (convs, states) = _layer_scan(
        body, x, (params["layers"], cache["conv"], cache["state"]), cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    return logits.astype(jnp.float32), {"conv": convs, "state": states}


# ---------------------------------------------------------------------------
# hybrid (zamba2): python loop; shared attention keeps per-site KV caches
# ---------------------------------------------------------------------------
def _attn_sites(cfg) -> list[int]:
    return [i for i in range(cfg.n_layers) if i % cfg.attn_every == 0]


def hybrid_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    positions = jnp.arange(s)
    x = _embed_inputs(params, batch, cfg)
    shared = params["shared"]
    sites = _attn_sites(cfg)
    ssm_caches, aks, avs = [], [], []
    for i in range(cfg.n_layers):
        if i in sites:
            h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(shared["attn"], h, cfg, positions)
            o = L.attention(q, k, v, cfg, causal=True)
            x = x + o.reshape(bsz, s, -1) @ shared["attn"]["wo"]
            x = x + L.mlp_block(shared["mlp"],
                                L.rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
            aks.append(k)
            avs.append(v)
        p = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        y, c = ssm_prefill_block(p["ssm"],
                                 L.rms_norm(x, p["ln"], cfg.norm_eps), cfg)
        x = x + y
        ssm_caches.append(c)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    cache = {"ssm": jax.tree.map(lambda *a: jnp.stack(a), *ssm_caches),
             "attn": {"k": jnp.stack(aks), "v": jnp.stack(avs)}}
    return logits.astype(jnp.float32), cache


def hybrid_decode_step(params, cache, token, pos, cfg):
    bsz = token.shape[0]
    x = params["tok"]["emb"][token]
    positions = pos[None]
    shared = params["shared"]
    sites = _attn_sites(cfg)
    length = pos + 1
    new_ssm, new_k, new_v = [], [], []
    for i in range(cfg.n_layers):
        if i in sites:
            s_i = sites.index(i)
            h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(shared["attn"], h, cfg, positions)
            kc = jax.lax.dynamic_update_slice_in_dim(cache["attn"]["k"][s_i],
                                                     k, pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["attn"]["v"][s_i],
                                                     v, pos, axis=1)
            o = L.decode_attention(q, kc, vc, length, cfg)
            x = x + o.reshape(bsz, 1, -1) @ shared["attn"]["wo"]
            x = x + L.mlp_block(shared["mlp"],
                                L.rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
            new_k.append(kc)
            new_v.append(vc)
        p = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        c = jax.tree.map(lambda a, i=i: a[i], cache["ssm"])
        y, c2 = ssm_decode_block(p["ssm"],
                                 L.rms_norm(x, p["ln"], cfg.norm_eps), c, cfg)
        x = x + y
        new_ssm.append(c2)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    cache = {"ssm": jax.tree.map(lambda *a: jnp.stack(a), *new_ssm),
             "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)}}
    return logits.astype(jnp.float32), cache


# ---------------------------------------------------------------------------
# paged KV-cache storage (the serving engine's block-granular data plane)
# ---------------------------------------------------------------------------
class KVPoolExhausted(RuntimeError):
    """The pool's byte budget cannot fit another block even after expiring
    overdue leases — callers defer admission until completions free blocks."""


class KVBlock(NamedTuple):
    """One stored KV block: ``key`` pins an arena slot (or a serialized
    object on channels without block reservation) holding a
    ``(2, L, ntok, KV, HD)`` slab — K stacked over V."""

    key: tuple
    ntok: int
    nbytes: int


class KVBlockPool:
    """Refcounted, leased, arena-backed KV-cache block storage.

    Replaces grow-by-concatenate caches with fixed-size *pages*: a
    request's KV occupies ``ceil(tokens / block_tokens)`` blocks, each one
    Store object whose lifetime is the ownership subsystem's —

    * ``put_block`` holds ONE reference per block (the owning request) and
      puts a TTL lease on it: when the request completes, :meth:`release`
      decrefs and the channel evicts the slot; when the request's worker
      crashes without releasing, the lease expires and the next pool under
      pressure (or an explicit ``sweep``) reclaims the slot;
    * on channels with ``supports_blocks`` (the shm arena) the block is
      written straight into the reserved slot view — no serializer and no
      staging copy; other channels fall back to an ordinary serialized put;
    * ``budget_bytes`` bounds the pool: an over-budget ``put_block``
      expires overdue leases first and raises :class:`KVPoolExhausted` if
      still full — the engine's admission control defers the request.
    """

    def __init__(self, store, cfg, *, block_tokens: int = 16,
                 budget_bytes: int | None = 64 << 20,
                 lease_ttl: float | None = 60.0) -> None:
        from repro.core.serialize import _resolve_dtype

        self.store = store
        self.block_tokens = int(block_tokens)
        self.budget_bytes = budget_bytes
        self.lease_ttl = lease_ttl
        self.n_layers = cfg.n_layers
        self.n_kv_heads = cfg.n_kv_heads
        self.head_dim = cfg.hd
        self.dtype = _resolve_dtype(cfg.dtype)
        self._direct = getattr(store.connector, "supports_blocks", False)
        self._blocks: dict[tuple, KVBlock] = {}   # key -> tracked block

    # -- write path ----------------------------------------------------------
    def put_block(self, k, v) -> KVBlock:
        """Store one block. ``k``/``v``: (L, t, KV, HD) host arrays with
        t <= block_tokens."""
        k = np.ascontiguousarray(k)
        v = np.ascontiguousarray(v)
        ntok = k.shape[1]
        nbytes = k.nbytes + v.nbytes
        self._ensure_budget(nbytes)
        if self._direct:
            key, view = self.store.reserve_block(nbytes)
            flat = np.frombuffer(view, self.dtype)
            flat[:k.size] = k.ravel()
            flat[k.size:k.size + v.size] = v.ravel()
            self.store.commit_block(key)
        else:
            key = self.store.put(np.stack([k, v]))
        self.store.incref(key)               # the owning request's reference
        if self.lease_ttl:
            self.store.lease(key, self.lease_ttl)   # crashed-owner backstop
        blk = KVBlock(tuple(key), ntok, nbytes)
        self._blocks[blk.key] = blk
        return blk

    def put_prefill(self, k, v) -> list[KVBlock]:
        """Page a prefilled cache — ``k``/``v``: (L, plen, KV, HD) — into
        block_tokens-sized blocks."""
        t = k.shape[1]
        return [self.put_block(k[:, s:s + self.block_tokens],
                               v[:, s:s + self.block_tokens])
                for s in range(0, t, self.block_tokens)]

    # -- read path -----------------------------------------------------------
    def read_block(self, blk: KVBlock):
        """(k, v) arrays of one block — zero-copy views of the arena slot
        on block-capable channels (stable while the block's key is pinned)."""
        if self._direct:
            raw = self.store.block_view(blk.key)
            if raw is None:
                raise LookupError(f"KV block {blk.key} is gone "
                                  f"(evicted or lease-expired)")
            arr = np.frombuffer(raw, self.dtype).reshape(
                2, self.n_layers, blk.ntok, self.n_kv_heads, self.head_dim)
        else:
            obj = self.store.get(blk.key)
            if obj is None:
                raise LookupError(f"KV block {blk.key} is gone "
                                  f"(evicted or lease-expired)")
            arr = obj
        return arr[0], arr[1]

    def gather(self, blocks: list[KVBlock]):
        """Assemble a request's blocks into dense (L, T, KV, HD) k/v
        arrays (the admission path: blocks -> a working-cache row)."""
        ks, vs = zip(*(self.read_block(b) for b in blocks))
        return (np.concatenate(ks, axis=1) if len(ks) > 1 else ks[0],
                np.concatenate(vs, axis=1) if len(vs) > 1 else vs[0])

    # -- lifetime ------------------------------------------------------------
    def release(self, blocks: list[KVBlock]) -> None:
        """Drop the owning references (request completion): each block's
        refcount hits zero and the channel evicts/frees its slot."""
        for blk in blocks:
            self._blocks.pop(blk.key, None)
            self.store.decref(blk.key)

    def touch(self, blocks: list[KVBlock]) -> None:
        """Refresh the leases of a live request's blocks (the heartbeat a
        long-running generation sends so its pages outlive lease_ttl)."""
        if self.lease_ttl:
            for blk in blocks:
                self.store.lease(blk.key, self.lease_ttl)

    def sweep(self) -> int:
        """Expire overdue leases now (reclaiming crashed owners' blocks);
        returns the number of keys reclaimed."""
        n = self.store.sweep_leases()
        if n:
            self._prune()
        return n

    # -- accounting ----------------------------------------------------------
    def _prune(self) -> None:
        dead = [key for key in self._blocks if not self.store.exists(key)]
        for key in dead:
            self._blocks.pop(key, None)

    def bytes_in_use(self) -> int:
        return sum(b.nbytes for b in self._blocks.values())

    def _ensure_budget(self, nbytes: int) -> None:
        if self.budget_bytes is None:
            return
        if self.bytes_in_use() + nbytes <= self.budget_bytes:
            return
        self.sweep()                    # reclaim crashed owners' blocks
        self._prune()
        used = self.bytes_in_use()
        if used + nbytes > self.budget_bytes:
            raise KVPoolExhausted(
                f"KV pool over budget: {used} + {nbytes} > "
                f"{self.budget_bytes} bytes ({len(self._blocks)} blocks)")

    def stats(self) -> dict[str, Any]:
        return {"n_blocks": len(self._blocks),
                "bytes_in_use": self.bytes_in_use(),
                "budget_bytes": self.budget_bytes,
                "block_tokens": self.block_tokens,
                "direct": self._direct}
