"""Serving paths: prefill (build cache) + single-token decode, per family.

Cache layouts (leading L dim so layer scans carry them):
  attention: {"k","v": (L, B, S_c, KV, HD)}  S_c = sliding window if set
  audio:     + {"ck","cv": (L, B, F, KV, HD)} cross-attn KV (precomputed)
  ssm:       {"conv": (L, B, K-1, cd), "state": (L, B, H, P, N)}
  hybrid:    {"ssm": ..., "attn": {"k","v": (n_sites, B, S_c, H, HD)}}

Ring-buffer semantics for sliding windows: slot = pos % W; validity by
count, not order (softmax is order-invariant; RoPE is baked in at write).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_as
from repro.models import layers as L
from repro.models.moe import moe_block, moe_decode
from repro.models.ssm import (init_ssm_cache, ssm_decode_block,
                              ssm_prefill_block)
from repro.models.transformer import (_embed_inputs, _encode,
                                      _shared_attn_block, _maybe_remat)


def _layer_scan(body, carry, xs, cfg):
    """lax.scan over the layer stack, or an unrolled loop when
    cfg.scan_layers is False (the dry-run cost variant needs unrolled HLO
    because XLA cost analysis counts while-loop bodies once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        out = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        out = None
    return carry, out


def _cache_len(cfg, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def _shard_cache(t):
    return shard_as(t, "batch", "cache_seq", "kv_heads", "hd_tp")


# ---------------------------------------------------------------------------
# dense / moe / vlm decoder
# ---------------------------------------------------------------------------
def decoder_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    positions = jnp.arange(s)
    w = _cache_len(cfg, s)
    x = _embed_inputs(params, batch, cfg)

    def body(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        o = L.attention(q, k, v, cfg, causal=True)
        x = x + shard_as(o.reshape(bsz, s, -1) @ p["attn"]["wo"],
                         "batch", "act_seq", "embed")
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(p["moe"], h2, cfg)
        else:
            y = L.mlp_block(p["mlp"], h2, cfg)
        x = shard_as(x + y, "batch", "act_seq", "embed")
        kc = _shard_cache(k[:, s - w:])
        vc = _shard_cache(v[:, s - w:])
        return x, (kc, vc)

    x, (ks, vs) = _layer_scan(_maybe_remat(body, cfg), x, params["layers"], cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


def decoder_decode_step(params, cache, token, pos, cfg):
    """token: (B, 1) int32; pos: scalar int32 (next position index)."""
    bsz = token.shape[0]
    x = params["tok"]["emb"][token]
    positions = pos[None] if pos.ndim == 0 else pos
    w = cache["k"].shape[2]
    slot = pos % w if cfg.sliding_window else pos
    length = jnp.minimum(pos + 1, w)

    def body(x, p_kv):
        p, kc, vc = p_kv
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = L.decode_attention(q, kc, vc, length, cfg)
        x = x + o.reshape(bsz, 1, -1) @ p["attn"]["wo"]
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_decode(p["moe"], h2, cfg)
        else:
            y = L.mlp_block(p["mlp"], h2, cfg)
        return x + y, (kc, vc)

    x, (ks, vs) = _layer_scan(body, x, (params["layers"], cache["k"],
                                        cache["v"]), cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# whisper-style enc-dec
# ---------------------------------------------------------------------------
def audio_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    memory = _encode(params, batch["frames"], cfg)
    positions = jnp.arange(s)
    x = params["tok"]["emb"][tokens]
    f = memory.shape[1]
    kv, hd = cfg.n_kv_heads, cfg.hd

    def body(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        o = L.attention(q, k, v, cfg, causal=True)
        x = x + o.reshape(bsz, s, -1) @ p["attn"]["wo"]
        hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        ck = (memory @ p["xattn"]["wk"]).reshape(bsz, f, kv, hd)
        cv = (memory @ p["xattn"]["wv"]).reshape(bsz, f, kv, hd)
        qx = (hx @ p["xattn"]["wq"]).reshape(bsz, s, cfg.n_heads, hd)
        ox = L.attention(qx, ck, cv, cfg, causal=False)
        x = x + ox.reshape(bsz, s, -1) @ p["xattn"]["wo"]
        x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = _layer_scan(_maybe_remat(body, cfg), x,
                                        params["layers"], cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs, "ck": cks, "cv": cvs}


def audio_decode_step(params, cache, token, pos, cfg):
    bsz = token.shape[0]
    x = params["tok"]["emb"][token]
    positions = pos[None]
    f = cache["ck"].shape[2]
    length = pos + 1

    def body(x, p_kv):
        p, kc, vc, ck, cv = p_kv
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        o = L.decode_attention(q, kc, vc, length, cfg)
        x = x + o.reshape(bsz, 1, -1) @ p["attn"]["wo"]
        hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"]).reshape(bsz, 1, cfg.n_heads, cfg.hd)
        ox = L.decode_attention(qx, ck, cv, f, cfg)
        x = x + ox.reshape(bsz, 1, -1) @ p["xattn"]["wo"]
        x = x + L.mlp_block(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x, (kc, vc)

    x, (ks, vs) = _layer_scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["ck"],
                  cache["cv"]), cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs, "ck": cache["ck"],
                                        "cv": cache["cv"]}


# ---------------------------------------------------------------------------
# ssm
# ---------------------------------------------------------------------------
def ssm_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    x = _embed_inputs(params, batch, cfg)

    def body(x, p):
        y, c = ssm_prefill_block(p["ssm"],
                                 L.rms_norm(x, p["ln"], cfg.norm_eps), cfg)
        return shard_as(x + y, "batch", "act_seq", "embed"), c

    x, cache = _layer_scan(_maybe_remat(body, cfg), x, params["layers"], cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    return logits.astype(jnp.float32), cache


def ssm_decode_step(params, cache, token, pos, cfg):
    x = params["tok"]["emb"][token]

    def body(x, p_c):
        p, conv, state = p_c
        y, c = ssm_decode_block(p["ssm"],
                                L.rms_norm(x, p["ln"], cfg.norm_eps),
                                {"conv": conv, "state": state}, cfg)
        return x + y, (c["conv"], c["state"])

    x, (convs, states) = _layer_scan(
        body, x, (params["layers"], cache["conv"], cache["state"]), cfg)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    return logits.astype(jnp.float32), {"conv": convs, "state": states}


# ---------------------------------------------------------------------------
# hybrid (zamba2): python loop; shared attention keeps per-site KV caches
# ---------------------------------------------------------------------------
def _attn_sites(cfg) -> list[int]:
    return [i for i in range(cfg.n_layers) if i % cfg.attn_every == 0]


def hybrid_prefill(params, batch, cfg):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    positions = jnp.arange(s)
    x = _embed_inputs(params, batch, cfg)
    shared = params["shared"]
    sites = _attn_sites(cfg)
    ssm_caches, aks, avs = [], [], []
    for i in range(cfg.n_layers):
        if i in sites:
            h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(shared["attn"], h, cfg, positions)
            o = L.attention(q, k, v, cfg, causal=True)
            x = x + o.reshape(bsz, s, -1) @ shared["attn"]["wo"]
            x = x + L.mlp_block(shared["mlp"],
                                L.rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
            aks.append(k)
            avs.append(v)
        p = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        y, c = ssm_prefill_block(p["ssm"],
                                 L.rms_norm(x, p["ln"], cfg.norm_eps), cfg)
        x = x + y
        ssm_caches.append(c)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h[:, -1:], cfg)[:, 0]
    cache = {"ssm": jax.tree.map(lambda *a: jnp.stack(a), *ssm_caches),
             "attn": {"k": jnp.stack(aks), "v": jnp.stack(avs)}}
    return logits.astype(jnp.float32), cache


def hybrid_decode_step(params, cache, token, pos, cfg):
    bsz = token.shape[0]
    x = params["tok"]["emb"][token]
    positions = pos[None]
    shared = params["shared"]
    sites = _attn_sites(cfg)
    length = pos + 1
    new_ssm, new_k, new_v = [], [], []
    for i in range(cfg.n_layers):
        if i in sites:
            s_i = sites.index(i)
            h = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(shared["attn"], h, cfg, positions)
            kc = jax.lax.dynamic_update_slice_in_dim(cache["attn"]["k"][s_i],
                                                     k, pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["attn"]["v"][s_i],
                                                     v, pos, axis=1)
            o = L.decode_attention(q, kc, vc, length, cfg)
            x = x + o.reshape(bsz, 1, -1) @ shared["attn"]["wo"]
            x = x + L.mlp_block(shared["mlp"],
                                L.rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
            new_k.append(kc)
            new_v.append(vc)
        p = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        c = jax.tree.map(lambda a, i=i: a[i], cache["ssm"])
        y, c2 = ssm_decode_block(p["ssm"],
                                 L.rms_norm(x, p["ln"], cfg.norm_eps), c, cfg)
        x = x + y
        new_ssm.append(c2)
    h = L.rms_norm(x, params["tok"]["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["tok"], h, cfg)[:, 0]
    cache = {"ssm": jax.tree.map(lambda *a: jnp.stack(a), *new_ssm),
             "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)}}
    return logits.astype(jnp.float32), cache
