"""Mamba2 / SSD mixer (state-space duality, arXiv:2405.21060).

Chunked "dual form": within-chunk attention-like einsums + an inter-chunk
linear recurrence over per-chunk states.  ngroups=1 (B, C shared across
heads).  The chunked scan body is the compute hot-spot the Pallas
``ssd_scan`` kernel replaces on TPU (cfg.attention_impl == "pallas").

Block layout (per layer):
  in_proj: D -> [z (di), xBC (di + 2*N), dt (nh)]
  causal depthwise conv (K=4) over xBC; silu
  SSD(x, dt, A, B, C) + D*x skip
  gated RMSNorm: rms(y * silu(z)) ; out_proj: di -> D
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_as
from repro.models.layers import dtype_of, rms_norm


def _segsum(x):
    """Lower-triangular cumulative sums: out[..., i, j] = sum_{j<k<=i} x[k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """SSD scan (reference jnp path).

    x: (B, L, H, P)   inputs (already multiplied by nothing; dt applied here)
    dt: (B, L, H)     positive step sizes
    a_log: (H,)       A = -exp(a_log)
    b, c: (B, L, N)   input/output projections (ngroups=1, shared over heads)
    returns y: (B, L, H, P)
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    if l % q:  # zero-pad: dta=0 (decay 1) + zero injection leaves states exact
        pad = q - l % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    l_pad = x.shape[1]
    nc = l_pad // q

    a = -jnp.exp(a_log.astype(jnp.float32))                     # (H,)
    dta = dt.astype(jnp.float32) * a                            # (B, L, H)
    xdt = x * dt[..., None].astype(x.dtype)                     # fold dt into x

    # chunk views
    xc = xdt.reshape(bs, nc, q, h, p)
    bc = b.reshape(bs, nc, q, n)
    cc = c.reshape(bs, nc, q, n)
    dtac = dta.reshape(bs, nc, q, h).transpose(0, 3, 1, 2)      # (B, H, nc, Q)
    dtac = shard_as(dtac, "batch", "ssm_heads", None, None)

    cum = jnp.cumsum(dtac, axis=-1)                             # (B, H, nc, Q)
    # 1) within-chunk (dual quadratic form)
    decay = jnp.exp(_segsum(dtac))                              # (B,H,nc,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", cc, bc,
                        preferred_element_type=jnp.float32)     # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcqs,bhcqs,bcshp->bcqhp", scores, decay, xc,
                        preferred_element_type=jnp.float32)

    # 2) per-chunk final states
    decay_states = jnp.exp(cum[..., -1:] - cum)                 # (B,H,nc,Q)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", bc, decay_states, xc,
                        preferred_element_type=jnp.float32)     # (B,nc,H,P,N)

    # 3) inter-chunk recurrence  s_{c} = exp(sum dta_c) * s_{c-1} + states_c
    chunk_decay = jnp.exp(cum[..., -1]).transpose(0, 2, 1)      # (B, nc, H)

    def step(s_prev, inp):
        dec, st = inp  # (B, H), (B, H, P, N)
        s = dec[..., None, None] * s_prev + st
        return s, s_prev  # emit the state ENTERING this chunk

    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    s_final, states_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)                   # (B,nc,H,P,N)

    # 4) state -> output contribution
    state_decay = jnp.exp(cum)                                  # (B,H,nc,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", cc, states_in, state_decay,
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(bs, l_pad, h, p)[:, :l]
    return y.astype(x.dtype), s_final


def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t):
    """One-token SSD recurrence.

    state: (B, H, P, N) fp32; x_t: (B, H, P); dt_t: (B, H); b_t/c_t: (B, N)
    returns (y_t: (B, H, P), new_state)
    """
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt_t.astype(jnp.float32) * a                          # (B, H)
    decay = jnp.exp(dta)[..., None, None]                       # (B,H,1,1)
    xdt = (x_t * dt_t[..., None]).astype(jnp.float32)
    inject = jnp.einsum("bhp,bn->bhpn", xdt, b_t.astype(jnp.float32))
    new_state = decay * state + inject
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------
def init_ssm(key, cfg):
    d = cfg.d_model
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    dt_ = dtype_of(cfg)
    proj_out = 2 * di + 2 * n + nh
    return {
        "in_proj": jax.random.normal(ks[0], (d, proj_out), dt_) * std,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dt_) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dt_),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), dt_)
        * (std / math.sqrt(2 * cfg.n_layers)),
    }


def _split_proj(zxbcdt, cfg):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt_raw = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt_raw


def ssm_block(p, x, cfg):
    """Mamba2 block over a full sequence. x: (B, S, D)."""
    bsz, s, _ = x.shape
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)

    # causal depthwise conv over the sequence (kernel K)
    k = cfg.ssm_conv
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + s, :] * p["conv_w"][i] for i in range(k))
    xbc = jax.nn.silu(conv + p["conv_b"])

    xs = xbc[..., :di].reshape(bsz, s, nh, hp)
    xs = shard_as(xs, "batch", "seq", "ssm_heads", None)
    b = xbc[..., di:di + n]
    c = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cfg.attention_impl == "pallas":
        from repro.kernels.ops import ssd_scan as _ssd

        y = _ssd(xs, dt, p["a_log"], b, c, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xs, dt, p["a_log"], b, c, chunk=min(cfg.ssm_chunk, s))
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return shard_as(out, "batch", "act_seq", "embed")


def ssm_decode_block(p, x, cache, cfg):
    """One-token Mamba2 step.

    x: (B, 1, D); cache: {"conv": (B, K-1, conv_dim), "state": (B,H,P,N)}.
    """
    bsz = x.shape[0]
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)

    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,cd)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv)
    new_conv = hist[:, 1:]

    x_t = xbc_t[..., :di].reshape(bsz, nh, hp)
    b_t = xbc_t[..., di:di + n]
    c_t = xbc_t[..., di + n:]
    dt_t = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    y_t, new_state = ssd_decode_step(cache["state"], x_t, dt_t, p["a_log"],
                                     b_t, c_t)
    y_t = y_t + x_t * p["d_skip"][None, :, None].astype(x_t.dtype)
    y = y_t.reshape(bsz, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": new_conv, "state": new_state}


def init_ssm_cache(cfg, batch):
    di, n = cfg.d_inner, cfg.ssm_state
    conv_dim = di + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype_of(cfg)),
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, n),
                           jnp.float32),
    }


def ssm_prefill_block(p, x, cfg):
    """Full-sequence Mamba2 block that also returns the decode cache."""
    bsz, s, _ = x.shape
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(zxbcdt, cfg)

    k = cfg.ssm_conv
    pad = jnp.pad(xbc_raw, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + s, :] * p["conv_w"][i] for i in range(k))
    xbc = jax.nn.silu(conv + p["conv_b"])

    xs = xbc[..., :di].reshape(bsz, s, nh, hp)
    b = xbc[..., di:di + n]
    c = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    y, s_final = ssd_chunked(xs, dt, p["a_log"], b, c,
                             chunk=min(cfg.ssm_chunk, s))
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    cache = {"conv": xbc_raw[:, s - (k - 1):, :], "state": s_final}
    return out, cache
