"""Mixture-of-Experts FFN — GShard-style capacity dispatch (top-k, dropping).

Einsum formulation so GSPMD can shard it:
* experts dim -> 'experts' logical axis (EP when E divides the mesh axis,
  e.g. qwen3's 128 experts; otherwise the per-expert ff dim shards, e.g.
  mixtral's 8 experts with TP inside each expert),
* dispatch/combine tensors (G, S, E, C) shard on batch-group and experts,
* capacity C = ceil(S * top_k / E * capacity_factor); overflow tokens drop
  (residual passes through, standard for dropping MoE).

Router extras: load-balance aux loss (Switch) + router z-loss, both returned
for the train loss to weight.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_as
from repro.models.layers import dtype_of


def init_moe(key, cfg):
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    dt = dtype_of(cfg)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * std,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dt) * std,
        "w_up": jax.random.normal(ks[2], (e, d, f), dt) * std,
        "w_down": jax.random.normal(ks[3], (e, f, d), dt)
        * (std / math.sqrt(2 * cfg.n_layers)),
    }


def _capacity(tokens_per_group: int, cfg) -> int:
    c = math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                  * cfg.capacity_factor)
    return max(c, 1)


def _dispatch_einsum(x, idx, pos, keep, gate_vals, e, cap, cfg):
    """GShard dense one-hot dispatch: (x_e, combine tensor)."""
    dt = dtype_of(cfg)
    disp_e = jax.nn.one_hot(idx, e, dtype=dt)                     # (B, S, k, E)
    disp_c = jax.nn.one_hot(pos, cap, dtype=dt) * keep[..., None].astype(dt)
    dispatch = jnp.einsum("bske,bskc->bsec", disp_e, disp_c)
    dispatch = shard_as(dispatch, "batch", None, "experts", None)
    combine = jnp.einsum("bske,bskc,bsk->bsec", disp_e, disp_c,
                         gate_vals.astype(dt))
    combine = shard_as(combine, "batch", None, "experts", None)
    x_e = jnp.einsum("bsec,bsd->becd", dispatch, x)               # (B, E, C, D)
    return x_e, combine


def _dispatch_scatter(x, idx, pos, keep, gate_vals, e, cap, cfg):
    """Scatter/gather dispatch: O(S*k*D) instead of O(S*k*E*C).

    Returns (x_e, combine_fn) where combine_fn gathers expert outputs back
    to token order with gate weighting.
    """
    b, s, d = x.shape
    k = idx.shape[-1]
    dt = dtype_of(cfg)
    # flat slot id per (token, k): e * cap + pos; dropped tokens -> e*cap
    slot = jnp.where(keep, idx * cap + pos, e * cap)              # (B, S, k)
    slot_flat = slot.reshape(b, s * k)
    x_rep = jnp.repeat(x, k, axis=1)                              # (B, S*k, D)

    def scatter_row(slots_row, x_row):
        buf = jnp.zeros((e * cap + 1, d), dt)
        return buf.at[slots_row].add(x_row)

    x_e = jax.vmap(scatter_row)(slot_flat, x_rep)[:, :-1]         # drop sink
    x_e = x_e.reshape(b, e, cap, d)

    def combine_gather(y_e):
        y_flat = y_e.reshape(b, e * cap, d)
        sink = jnp.zeros((b, 1, d), y_flat.dtype)
        y_pad = jnp.concatenate([y_flat, sink], axis=1)
        gathered = jnp.take_along_axis(
            y_pad, slot_flat[..., None], axis=1)                  # (B, S*k, D)
        gathered = gathered.reshape(b, s, k, d)
        w = (gate_vals * keep).astype(gathered.dtype)
        return jnp.einsum("bskd,bsk->bsd", gathered, w)

    return x_e, combine_gather


def moe_block(p, x, cfg):
    """x: (B, S, D) -> (y: (B, S, D), aux: dict of router losses).

    Groups = batch rows (tokens never cross rows, so dispatch stays sharded
    over the batch axes).  Two dispatch implementations:

    * ``einsum`` (GShard classic): dense one-hot dispatch/combine tensors —
      MXU-friendly but costs O(S*k*E*C) extra FLOPs per layer, measured at
      ~the cost of the experts themselves for mixtral (EXPERIMENTS §Perf);
    * ``scatter`` (default): segment-sum into capacity slots + gather back,
      O(S*k*D) — the beyond-paper optimization adopted after the hillclimb.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                # (B, S, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)   # renormalize top-k

    # position of each (token, k) inside its expert's capacity buffer
    expert_onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)      # (B, S, k, E)
    flat = expert_onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                            # (B, S*k, E)
    pos = (pos * flat).sum(-1).reshape(b, s, k)                   # (B, S, k)
    keep = pos < cap                                              # drop overflow

    dt = dtype_of(cfg)
    if cfg.moe_impl == "scatter":
        x_e, combine_gather = _dispatch_scatter(x, idx, pos, keep, gate_vals,
                                                e, cap, cfg)
    else:
        x_e, combine = _dispatch_einsum(x, idx, pos, keep, gate_vals, e, cap,
                                        cfg)
    x_e = shard_as(x_e, "batch", "experts", None, None)
    h = jnp.einsum("becd,edf->becf", x_e, p["w_up"])
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", x_e, p["w_gate"])) * h
    h = shard_as(h, "batch", "experts", None, "moe_ff")
    y_e = jnp.einsum("becf,efd->becd", h, p["w_down"])
    if cfg.moe_impl == "scatter":
        y = combine_gather(y_e)
    else:
        y = jnp.einsum("bsec,becd->bsd", combine, y_e)
    y = shard_as(y, "batch", "act_seq", "embed")

    # -- router losses -----------------------------------------------------
    # load-balance: mean fraction of tokens per expert x mean router prob
    me = jnp.mean(expert_onehot.astype(jnp.float32).sum(2), axis=(0, 1))  # (E,)
    ce = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(me * ce) / 1.0
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}


def moe_decode(p, x, cfg):
    """Decode-time MoE: one token per row. x: (B, 1, D).

    The whole batch forms ONE dispatch group so the capacity buffer stays at
    ~B*top_k*cf/E slots per expert instead of all-experts-per-token.
    """
    b, s, d = x.shape
    assert s == 1
    xt = x.reshape(1, b, d)  # group over batch
    sub = cfg.replace(capacity_factor=max(cfg.capacity_factor, 2.0))
    y, aux = moe_block(p, xt, sub)
    return y.reshape(b, 1, d), aux
