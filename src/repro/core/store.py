"""The Store (paper §3.5): object-level interface over a Connector.

* (de)serializes Python objects / JAX pytrees (custom hooks registerable),
* caches *after deserialization* (paper: "to avoid duplicate deserializations"),
* ``proxy()`` / ``proxy_batch()`` produce transparent lazy proxies whose
  factories carry only ``(store config, key)``,
* an ``evict`` flag on proxies evicts the object on first resolve (ephemeral
  intermediates),
* ``resolve_async`` overlaps proxy resolution with compute,
* stores register globally by name: a proxy resolved on a process without the
  store re-materializes it from the factory's embedded config, and later
  proxies reuse the registered instance (shared caches, live connections).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.connector import (Connector, Key, import_path,
                                  resolve_import_path)
from repro.core.proxy import Proxy, get_factory, is_proxy
from repro.core.serialize import deserialize, frame_nbytes, serialize

_REGISTRY: dict[str, "Store"] = {}
_REGISTRY_LOCK = threading.RLock()
_RESOLVE_POOL: ThreadPoolExecutor | None = None
_POOL_LOCK = threading.Lock()


def _pool() -> ThreadPoolExecutor:
    global _RESOLVE_POOL
    with _POOL_LOCK:
        if _RESOLVE_POOL is None:
            _RESOLVE_POOL = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="psj-resolve")
        return _RESOLVE_POOL


class _LRUCache:
    def __init__(self, maxsize: int) -> None:
        self.maxsize = maxsize
        self._data: OrderedDict[Key, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Key, default=None):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return default

    def put(self, key: Key, value: Any) -> None:
        if self.maxsize <= 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def pop(self, key: Key) -> None:
        with self._lock:
            self._data.pop(key, None)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


@dataclass
class StoreConfig:
    name: str
    connector_path: str
    connector_config: dict[str, Any]
    cache_size: int = 16

    def build(self) -> "Store":
        cls = resolve_import_path(self.connector_path)
        connector = cls(**self.connector_config)
        return Store(self.name, connector, cache_size=self.cache_size)


@dataclass
class StoreFactory:
    """Callable that retrieves ``key`` from the named store.

    Self-contained (paper §3.3): includes everything needed to re-create the
    Store on any process.  Supports async pre-resolution via ``resolve_async``
    (the Future intentionally does not survive pickling).
    """

    key: Key
    store_config: StoreConfig
    evict: bool = False
    _future: Future | None = field(default=None, repr=False, compare=False)

    def __call__(self) -> Any:
        fut, self._future = self._future, None
        if fut is not None:
            return fut.result()
        return self._fetch()

    def _fetch(self) -> Any:
        store = get_or_create_store(self.store_config)
        obj = store.get(self.key)
        if obj is None and not store.exists(self.key):
            raise LookupError(
                f"key {self.key} not found in store {self.store_config.name!r}")
        if self.evict:
            store.evict(self.key)
        return obj

    def resolve_async(self) -> None:
        if self._future is None:
            self._future = _pool().submit(self._fetch)

    def __getstate__(self):
        state = self.__dict__.copy()
        state["_future"] = None
        return state


class Store:
    def __init__(self, name: str, connector: Connector, *,
                 cache_size: int = 16,
                 serializer: Callable[[Any], bytes] | None = None,
                 deserializer: Callable[[bytes], Any] | None = None,
                 register: bool = True) -> None:
        self.name = name
        self.connector = connector
        self._serialize = serializer or serialize
        self._deserialize = deserializer or deserialize
        self.cache = _LRUCache(cache_size)
        self.cache_size = cache_size
        if register:
            register_store(self)

    # -- config round trip -----------------------------------------------------
    def config(self) -> StoreConfig:
        return StoreConfig(
            name=self.name,
            connector_path=import_path(type(self.connector)),
            connector_config=self.connector.config(),
            cache_size=self.cache_size,
        )

    # -- object ops --------------------------------------------------------------
    def put(self, obj: Any, **kwargs) -> Key:
        return self.connector.put(self._serialize(obj), **kwargs) \
            if kwargs else self.connector.put(self._serialize(obj))

    def put_batch(self, objs: Sequence[Any]) -> list[Key]:
        return self.connector.put_batch([self._serialize(o) for o in objs])

    def get(self, key: Key, default: Any = None) -> Any:
        key = tuple(key)
        cached = self.cache.get(key, _MISS)
        if cached is not _MISS:
            return cached
        blob = self.connector.get(key)
        if blob is None:
            return default
        obj = self._deserialize(blob)
        self.cache.put(key, obj)  # cache post-deserialization (paper §3.5)
        return obj

    def get_batch(self, keys: Sequence[Key], default: Any = None) -> list[Any]:
        """Fetch many objects in ONE batched connector exchange.

        Cache hits are served locally; the misses go through
        ``connector.get_batch`` (a single pipelined ``mget2`` on KV-backed
        connectors) and are deserialized + cached like ``get``.
        """
        keys = [tuple(k) for k in keys]
        out: list[Any] = [default] * len(keys)
        miss_idx: list[int] = []
        for i, k in enumerate(keys):
            cached = self.cache.get(k, _MISS)
            if cached is not _MISS:
                out[i] = cached
            else:
                miss_idx.append(i)
        if miss_idx:
            blobs = self.connector.get_batch([keys[i] for i in miss_idx])
            for i, blob in zip(miss_idx, blobs):
                if blob is None:
                    continue
                obj = self._deserialize(blob)
                self.cache.put(keys[i], obj)
                out[i] = obj
        return out

    # -- future-returning async ops ---------------------------------------------
    def put_async(self, obj: Any) -> Future:
        """Serialize + store off-thread; ``Future[Key]``.  Many in-flight
        puts share the connector's pipelined connection."""
        return _pool().submit(self.put, obj)

    def get_async(self, key: Key, default: Any = None) -> Future:
        """Fetch + deserialize off-thread; ``Future[Any]``."""
        return _pool().submit(self.get, key, default)

    def exists(self, key: Key) -> bool:
        return tuple(key) in self.cache or self.connector.exists(tuple(key))

    def evict(self, key: Key) -> None:
        key = tuple(key)
        self.cache.pop(key)
        self.connector.evict(key)

    # -- the proxy interface -----------------------------------------------------
    def proxy(self, obj: Any, evict: bool = False) -> Proxy:
        key = self.put(obj)
        return self.proxy_from_key(key, evict=evict)

    def proxy_from_key(self, key: Key, evict: bool = False) -> Proxy:
        return Proxy(StoreFactory(key=tuple(key), store_config=self.config(),
                                  evict=evict))

    def proxy_batch(self, objs: Sequence[Any], evict: bool = False) -> list[Proxy]:
        keys = self.put_batch(objs)  # single batch op (e.g. one Globus task)
        return [self.proxy_from_key(k, evict=evict) for k in keys]

    # -- perf counters -----------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Perf counters: LRU cache hits/misses plus connector/server stats
        where the connector exposes them (KV-backed connectors report the
        server's object count / byte total / op count)."""
        out: dict[str, Any] = {
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_len": len(self.cache),
            "cache_maxsize": self.cache.maxsize,
        }
        conn_stats = getattr(self.connector, "stats", None)
        if callable(conn_stats):
            try:
                out["connector"] = conn_stats()
            except (ConnectionError, OSError):  # server gone: counters only
                out["connector"] = None
        return out

    def close(self, *, close_connector: bool = True) -> None:
        unregister_store(self.name)
        if close_connector:
            self.connector.close()

    def __repr__(self) -> str:
        return f"Store(name={self.name!r}, connector={type(self.connector).__name__})"


_MISS = object()


# ---------------------------------------------------------------------------
# global registry (paper §3.5)
# ---------------------------------------------------------------------------
def register_store(store: Store) -> None:
    with _REGISTRY_LOCK:
        existing = _REGISTRY.get(store.name)
        if existing is not None and existing is not store:
            raise ValueError(f"store {store.name!r} already registered")
        _REGISTRY[store.name] = store


def unregister_store(name: str) -> None:
    with _REGISTRY_LOCK:
        _REGISTRY.pop(name, None)


def get_store(name: str) -> Store | None:
    with _REGISTRY_LOCK:
        return _REGISTRY.get(name)


def get_or_create_store(config: StoreConfig) -> Store:
    with _REGISTRY_LOCK:
        store = _REGISTRY.get(config.name)
        if store is None:
            store = config.build()  # Store() self-registers
        return store


# ---------------------------------------------------------------------------
# proxy helpers
# ---------------------------------------------------------------------------
def _fetch_group(config: StoreConfig, factories: list[StoreFactory],
                 futures: list[Future]) -> None:
    """Resolve a same-store batch of factories with ONE connector exchange."""
    try:
        store = get_or_create_store(config)
        objs = store.get_batch([f.key for f in factories])
        for factory, fut, obj in zip(factories, futures, objs):
            if fut.done():
                continue
            if obj is None and not store.exists(factory.key):
                fut.set_exception(LookupError(
                    f"key {factory.key} not found in store "
                    f"{config.name!r}"))
                continue
            if factory.evict:
                store.evict(factory.key)
            fut.set_result(obj)
    except BaseException as e:  # noqa: BLE001 - deliver into the futures
        for fut in futures:
            if not fut.done():
                fut.set_exception(e)


def resolve_async(proxy: "Proxy | Sequence[Proxy]") -> None:
    """Begin resolving proxies in the background (paper §3.5).

    Accepts one proxy or a sequence.  Batches are grouped by store, and
    each group is fetched with a single ``Store.get_batch`` — on KV-backed
    connectors that is ONE pipelined ``mget2`` round trip for the whole
    batch, overlapped with the caller's compute.
    """
    proxies = [proxy] if is_proxy(proxy) else list(proxy)
    groups: dict[str, list[StoreFactory]] = {}
    for p in proxies:
        factory = get_factory(p)
        if isinstance(factory, StoreFactory) and factory._future is None:
            groups.setdefault(factory.store_config.name, []).append(factory)
    for factories in groups.values():
        if len(factories) == 1:
            factories[0].resolve_async()
            continue
        futures: list[Future] = [Future() for _ in factories]
        for factory, fut in zip(factories, futures):
            factory._future = fut
        _pool().submit(_fetch_group, factories[0].store_config, factories,
                       futures)


def maybe_proxy(store: Store, obj: Any, threshold_bytes: int = 0) -> Any:
    """Proxy ``obj`` through ``store`` if it serializes above the threshold.

    The Colmena-integration pattern (§5.2): small objects ride the control
    plane, large ones go by proxy.
    """
    if is_proxy(obj):
        return obj
    # The store's *configured* serializer decides size and produces the
    # stored blob — a custom serializer= must see the same bytes its
    # deserializer= will get back, and we serialize exactly once.
    blob = store._serialize(obj)
    if frame_nbytes(blob) < threshold_bytes:
        return obj
    key = store.connector.put(blob)
    return store.proxy_from_key(key)
