"""The Store (paper §3.5): object-level interface over a Connector.

* (de)serializes Python objects / JAX pytrees (custom hooks registerable),
* caches *after deserialization* (paper: "to avoid duplicate deserializations"),
* ``proxy()`` / ``proxy_batch()`` produce transparent lazy proxies whose
  factories carry only ``(store config, key)``,
* object lifetimes are *reference counted* (the ownership subsystem,
  following arXiv:2407.01764): ``evict=True`` proxies are refcounted
  ephemerals (each sibling holds a reference, dropped on resolve; the key
  is evicted exactly once, after the LAST consumer — not on the first,
  which used to break every other consumer), ``owned_proxy()`` returns an
  :class:`~repro.core.OwnedProxy` whose reference is dropped on
  GC/release/context-exit, and ``lease()`` puts TTL bounds on keys so
  crashed reference holders can't leak them,
* ``resolve_async`` overlaps proxy resolution with compute,
* stores register globally by name: a proxy resolved on a process without the
  store re-materializes it from the factory's embedded config, and later
  proxies reuse the registered instance (shared caches, live connections).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.connector import (Connector, Key, import_path,
                                  resolve_import_path)
from repro.core.proxy import OwnedProxy, Proxy, get_factory, is_proxy
from repro.core.serialize import deserialize, frame_nbytes, serialize

_REGISTRY: dict[str, "Store"] = {}
_REGISTRY_LOCK = threading.RLock()
_RESOLVE_POOL: ThreadPoolExecutor | None = None
_POOL_LOCK = threading.Lock()


def _pool() -> ThreadPoolExecutor:
    global _RESOLVE_POOL
    with _POOL_LOCK:
        if _RESOLVE_POOL is None:
            _RESOLVE_POOL = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="psj-resolve")
        return _RESOLVE_POOL


class _LRUCache:
    def __init__(self, maxsize: int) -> None:
        self.maxsize = maxsize
        self._data: OrderedDict[Key, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Key, default=None):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return default

    def put(self, key: Key, value: Any) -> None:
        if self.maxsize <= 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def pop(self, key: Key) -> None:
        with self._lock:
            self._data.pop(key, None)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


@dataclass
class StoreConfig:
    name: str
    connector_path: str
    connector_config: dict[str, Any]
    cache_size: int = 16

    def build(self) -> "Store":
        cls = resolve_import_path(self.connector_path)
        connector = cls(**self.connector_config)
        try:
            return Store(self.name, connector, cache_size=self.cache_size)
        except BaseException:
            # we own this connector: a failed Store() (e.g. duplicate-name
            # registration) must not leak its sockets/servers/segments
            try:
                connector.close()
            except Exception:  # noqa: BLE001 - preserve the original error
                pass
            raise


@dataclass
class StoreFactory:
    """Callable that retrieves ``key`` from the named store.

    Self-contained (paper §3.3): includes everything needed to re-create the
    Store on any process.  Supports async pre-resolution via ``resolve_async``
    (the Future intentionally does not survive pickling).

    Lifetime semantics (the ownership subsystem):

    * ``evict=True`` — a *refcounted ephemeral*: the factory holds one
      reference to the key (acquired by ``Store.proxy(..., evict=True)``)
      and decrefs it after a successful resolve; the store evicts the key
      only when the LAST sibling's reference is dropped.  Pickling an
      unconsumed factory acquires a reference for the communicated sibling,
      so any number of consumers across processes resolve safely — this
      replaces the old fire-and-forget hard evict, whose first resolve
      broke every other consumer.
    * ``owned=True`` — the factory backs an :class:`~repro.core.OwnedProxy`:
      the reference is dropped by ``release()`` (GC/context-manager/explicit)
      rather than on resolve, and pickling clones a reference for the copy.
    * neither — a plain proxy: no lifetime bookkeeping at all.
    """

    key: Key
    store_config: StoreConfig
    evict: bool = False
    owned: bool = False
    _future: Future | None = field(default=None, repr=False, compare=False)
    _spent: bool = field(default=False, repr=False, compare=False)
    _borrows: int = field(default=0, repr=False, compare=False)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def _store(self) -> "Store":
        return get_or_create_store(self.store_config)

    def __call__(self) -> Any:
        fut, self._future = self._future, None
        if fut is not None:
            return fut.result()
        return self._fetch()

    def peek(self) -> Any:
        """Fetch the object WITHOUT consuming a reference (borrowed access)."""
        store = self._store()
        obj = store.get(self.key)
        if obj is None and not store.exists(self.key):
            raise LookupError(
                f"key {self.key} not found in store {self.store_config.name!r}")
        return obj

    def _fetch(self) -> Any:
        obj = self.peek()
        if self.evict and not self.owned:
            self._spend()            # decref-on-resolve; evicts at zero
        return obj

    def _spend(self) -> None:
        """Drop this factory's reference exactly once (thread-safe)."""
        with self._lock:
            if self._spent:
                return
            self._spent = True
        try:
            self._store().decref(self.key)
        except (ConnectionError, OSError):
            pass     # channel gone: the key's lease is the cleanup backstop

    # -- the lifetime protocol consumed by proxy.OwnedProxy/borrow/clone ----
    def release(self) -> None:
        """Drop an owned reference (OwnedProxy finalizer / explicit)."""
        with self._lock:
            if self._spent:
                return
            if self._borrows > 0:
                raise RuntimeError(
                    f"{self._borrows} borrowed prox(ies) still alive")
            self._spent = True
        try:
            self._store().decref(self.key)
        except (ConnectionError, OSError):
            pass

    def active_borrows(self) -> int:
        return self._borrows

    def add_borrow(self) -> None:
        with self._lock:
            if self._spent:
                raise RuntimeError("cannot borrow a released proxy")
            self._borrows += 1

    def drop_borrow(self) -> None:
        with self._lock:
            self._borrows = max(0, self._borrows - 1)

    def clone(self) -> "StoreFactory":
        """Acquire one more reference; a factory for a co-owning proxy."""
        with self._lock:
            if self._spent:
                # incref-ing a key whose last reference may already have
                # evicted it would create a phantom count on dead data
                raise RuntimeError(
                    "cannot clone a released or consumed proxy reference")
            # incref under the lock: a racing release() cannot drop the
            # last reference between the check and the acquisition
            self._store().incref(self.key)
        return StoreFactory(key=self.key, store_config=self.store_config,
                            owned=True)

    def into_owned(self) -> "StoreFactory":
        """Owning factory for this key.  An unconsumed ``evict=True``
        factory MOVES its pending reference (it will no longer decref on
        resolve); a plain factory acquires a fresh reference; an already
        consumed/released factory raises (its claim on the key is gone)."""
        if self.evict and not self.owned:
            with self._lock:
                if not self._spent:
                    self._spent = True   # steal the resolve-time reference
                    return StoreFactory(key=self.key,
                                        store_config=self.store_config,
                                        owned=True)
        return self.clone()

    def detached(self) -> "StoreFactory":
        """Plain non-owning factory for the same key (pickled borrows)."""
        return StoreFactory(key=self.key, store_config=self.store_config)

    def resolve_async(self) -> None:
        if self._future is None:
            self._future = _pool().submit(self._fetch)

    def __getstate__(self):
        state = self.__dict__.copy()
        state["_future"] = None
        state["_borrows"] = 0
        state.pop("_lock", None)
        # increfs happen under the lock so a racing release()/resolve
        # cannot drop the last reference between the check and acquisition
        with self._lock:
            if self.owned:
                if self._spent:
                    raise RuntimeError("cannot pickle a released OwnedProxy")
                # clone-on-pickle: the communicated copy owns its own ref
                self._store().incref(self.key)
            elif self.evict:
                if self._spent:
                    state["evict"] = False   # reference already consumed
                else:
                    # the communicated sibling carries its own reference,
                    # so N consumers across processes all resolve and the
                    # key dies exactly once, after the last of them
                    self._store().incref(self.key)
        state["_spent"] = False
        return state

    def __setstate__(self, state):
        state["_lock"] = threading.Lock()
        state.setdefault("_future", None)
        self.__dict__.update(state)


class Store:
    def __init__(self, name: str, connector: Connector, *,
                 cache_size: int = 16,
                 serializer: Callable[[Any], bytes] | None = None,
                 deserializer: Callable[[bytes], Any] | None = None,
                 register: bool = True) -> None:
        self.name = name
        self.connector = connector
        # register FIRST: a duplicate name must fail before this instance
        # builds any further state (and StoreConfig.build closes the
        # connector it constructed when this raises)
        if register:
            register_store(self)
        self._serialize = serializer or serialize
        self._deserialize = deserializer or deserialize
        self.cache = _LRUCache(cache_size)
        self.cache_size = cache_size

    # -- config round trip -----------------------------------------------------
    def config(self) -> StoreConfig:
        return StoreConfig(
            name=self.name,
            connector_path=import_path(type(self.connector)),
            connector_config=self.connector.config(),
            cache_size=self.cache_size,
        )

    # -- object ops --------------------------------------------------------------
    def put(self, obj: Any, **kwargs) -> Key:
        return self.connector.put(self._serialize(obj), **kwargs) \
            if kwargs else self.connector.put(self._serialize(obj))

    def put_batch(self, objs: Sequence[Any]) -> list[Key]:
        return self.connector.put_batch([self._serialize(o) for o in objs])

    def get(self, key: Key, default: Any = None) -> Any:
        key = tuple(key)
        cached = self.cache.get(key, _MISS)
        if cached is not _MISS:
            return cached
        blob = self.connector.get(key)
        if blob is None:
            return default
        obj = self._deserialize(blob)
        self.cache.put(key, obj)  # cache post-deserialization (paper §3.5)
        return obj

    def get_batch(self, keys: Sequence[Key], default: Any = None) -> list[Any]:
        """Fetch many objects in ONE batched connector exchange.

        Cache hits are served locally; the misses go through
        ``connector.get_batch`` (a single pipelined ``mget2`` on KV-backed
        connectors) and are deserialized + cached like ``get``.
        """
        keys = [tuple(k) for k in keys]
        out: list[Any] = [default] * len(keys)
        miss_idx: list[int] = []
        for i, k in enumerate(keys):
            cached = self.cache.get(k, _MISS)
            if cached is not _MISS:
                out[i] = cached
            else:
                miss_idx.append(i)
        if miss_idx:
            blobs = self.connector.get_batch([keys[i] for i in miss_idx])
            for i, blob in zip(miss_idx, blobs):
                if blob is None:
                    continue
                obj = self._deserialize(blob)
                self.cache.put(keys[i], obj)
                out[i] = obj
        return out

    # -- future-returning async ops ---------------------------------------------
    def put_async(self, obj: Any) -> Future:
        """Serialize + store off-thread; ``Future[Key]``.  Many in-flight
        puts share the connector's pipelined connection."""
        return _pool().submit(self.put, obj)

    def get_async(self, key: Key, default: Any = None) -> Future:
        """Fetch + deserialize off-thread; ``Future[Any]``."""
        return _pool().submit(self.get, key, default)

    def exists(self, key: Key) -> bool:
        key = tuple(key)
        if self.connector.exists(key):
            return True
        # the key is gone on the channel (evicted — possibly by another
        # consumer's decref): drop any stale deserialization-cache entry so
        # a local hit can't report a dead key as alive
        self.cache.pop(key)
        return False

    def evict(self, key: Key) -> None:
        key = tuple(key)
        self.cache.pop(key)
        self.connector.evict(key)
        # explicit evict is an override: lifecycle state dies with the
        # data (server-backed connectors do this in their _evict; local
        # fallback tables need the nudge)
        forget = getattr(self.connector, "_forget_lifetime", None)
        if forget is not None:
            forget(key)

    # -- lifecycle: refcounts + leases -------------------------------------------
    def incref(self, key: Key, n: int = 1) -> int:
        """Add ``n`` references to ``key``; returns the new count."""
        return int(self.connector.incref(tuple(key), n))

    def decref(self, key: Key, n: int = 1) -> int:
        """Drop ``n`` references; the connector evicts the key (exactly
        once) when the count reaches zero."""
        key = tuple(key)
        count = int(self.connector.decref(key, n))
        if count <= 0:
            self.cache.pop(key)
        return count

    def refcount(self, key: Key) -> int:
        return int(self.connector.refcount(tuple(key)))

    def lease(self, key: Key, ttl: float | None) -> bool:
        """Set/refresh a TTL lease on ``key`` (``None``/<=0 clears it): the
        channel evicts the key once the lease expires without a refresh,
        bounding leaks from reference holders that died.  Returns whether
        the key currently exists."""
        return bool(self.connector.touch(tuple(key), ttl))

    # -- the proxy interface -----------------------------------------------------
    def proxy(self, obj: Any, evict: bool = False,
              ttl: float | None = None) -> Proxy:
        key = self.put(obj)
        return self.proxy_from_key(key, evict=evict, ttl=ttl)

    def proxy_from_key(self, key: Key, evict: bool = False,
                       ttl: float | None = None) -> Proxy:
        key = tuple(key)
        if evict:
            # refcounted ephemeral: this sibling holds one reference,
            # dropped on resolve — the key dies after the LAST consumer
            self.connector.incref(key)
        if ttl is not None:
            # lease backstop: a pickled-but-never-delivered sibling (or a
            # consumer that dies before resolving) cannot leak the key
            self.connector.touch(key, ttl)
        return Proxy(StoreFactory(key=key, store_config=self.config(),
                                  evict=evict))

    def proxy_batch(self, objs: Sequence[Any], evict: bool = False,
                    ttl: float | None = None) -> list[Proxy]:
        keys = self.put_batch(objs)  # single batch op (e.g. one Globus task)
        if evict:
            self.connector.incref_batch([tuple(k) for k in keys])  # one exchange
        if ttl is not None:
            self.connector.touch_batch([tuple(k) for k in keys], ttl)
        if evict:
            config = self.config()
            return [Proxy(StoreFactory(key=tuple(k), store_config=config,
                                       evict=True)) for k in keys]
        return [self.proxy_from_key(k) for k in keys]

    def owned_proxy(self, obj: Any, ttl: float | None = None) -> OwnedProxy:
        """Proxy ``obj`` with an OWNED lifetime: the returned
        :class:`OwnedProxy` holds one reference, dropped when it is
        garbage-collected, released, or exits its ``with`` block — at zero
        references the key is evicted.  ``ttl`` additionally puts a lease
        on the key as a crash backstop."""
        return self.owned_proxy_from_key(self.put(obj), ttl=ttl)

    def owned_proxy_from_key(self, key: Key,
                             ttl: float | None = None) -> OwnedProxy:
        key = tuple(key)
        self.connector.incref(key)
        if ttl is not None:
            self.connector.touch(key, ttl)
        return OwnedProxy(StoreFactory(key=key, store_config=self.config(),
                                       owned=True))

    # -- perf counters -----------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Perf counters: LRU cache hits/misses plus connector/server stats
        where the connector exposes them (KV-backed connectors report the
        server's object count / byte total / op count)."""
        out: dict[str, Any] = {
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_len": len(self.cache),
            "cache_maxsize": self.cache.maxsize,
        }
        conn_stats = getattr(self.connector, "stats", None)
        if callable(conn_stats):
            try:
                out["connector"] = conn_stats()
            except (ConnectionError, OSError):  # server gone: counters only
                out["connector"] = None
        return out

    def close(self, *, close_connector: bool = True) -> None:
        unregister_store(self.name)
        if close_connector:
            self.connector.close()

    def __repr__(self) -> str:
        return f"Store(name={self.name!r}, connector={type(self.connector).__name__})"


_MISS = object()


# ---------------------------------------------------------------------------
# global registry (paper §3.5)
# ---------------------------------------------------------------------------
def register_store(store: Store) -> None:
    with _REGISTRY_LOCK:
        existing = _REGISTRY.get(store.name)
        if existing is not None and existing is not store:
            raise ValueError(f"store {store.name!r} already registered")
        _REGISTRY[store.name] = store


def unregister_store(name: str) -> None:
    with _REGISTRY_LOCK:
        _REGISTRY.pop(name, None)


def get_store(name: str) -> Store | None:
    with _REGISTRY_LOCK:
        return _REGISTRY.get(name)


def get_or_create_store(config: StoreConfig) -> Store:
    with _REGISTRY_LOCK:
        store = _REGISTRY.get(config.name)
        if store is None:
            store = config.build()  # Store() self-registers
        return store


# ---------------------------------------------------------------------------
# proxy helpers
# ---------------------------------------------------------------------------
def _fetch_group(config: StoreConfig, factories: list[StoreFactory],
                 futures: list[Future]) -> None:
    """Resolve a same-store batch of factories with ONE connector exchange."""
    try:
        store = get_or_create_store(config)
        objs = store.get_batch([f.key for f in factories])
        for factory, fut, obj in zip(factories, futures, objs):
            if fut.done():
                continue
            if obj is None and not store.exists(factory.key):
                fut.set_exception(LookupError(
                    f"key {factory.key} not found in store "
                    f"{config.name!r}"))
                continue
            if factory.evict and not factory.owned:
                factory._spend()     # drop this sibling's reference
            fut.set_result(obj)
    except BaseException as e:  # noqa: BLE001 - deliver into the futures
        for fut in futures:
            if not fut.done():
                fut.set_exception(e)


def resolve_async(proxy: "Proxy | Sequence[Proxy]") -> None:
    """Begin resolving proxies in the background (paper §3.5).

    Accepts one proxy or a sequence.  Batches are grouped by store, and
    each group is fetched with a single ``Store.get_batch`` — on KV-backed
    connectors that is ONE pipelined ``mget2`` round trip for the whole
    batch, overlapped with the caller's compute.
    """
    proxies = [proxy] if is_proxy(proxy) else list(proxy)
    groups: dict[str, list[StoreFactory]] = {}
    for p in proxies:
        factory = get_factory(p)
        if isinstance(factory, StoreFactory) and factory._future is None:
            groups.setdefault(factory.store_config.name, []).append(factory)
    for factories in groups.values():
        if len(factories) == 1:
            factories[0].resolve_async()
            continue
        futures: list[Future] = [Future() for _ in factories]
        for factory, fut in zip(factories, futures):
            factory._future = fut
        _pool().submit(_fetch_group, factories[0].store_config, factories,
                       futures)


def maybe_proxy(store: Store, obj: Any, threshold_bytes: int = 0) -> Any:
    """Proxy ``obj`` through ``store`` if it serializes above the threshold.

    The Colmena-integration pattern (§5.2): small objects ride the control
    plane, large ones go by proxy.
    """
    if is_proxy(obj):
        return obj
    # The store's *configured* serializer decides size and produces the
    # stored blob — a custom serializer= must see the same bytes its
    # deserializer= will get back, and we serialize exactly once.
    blob = store._serialize(obj)
    if frame_nbytes(blob) < threshold_bytes:
        return obj
    key = store.connector.put(blob)
    return store.proxy_from_key(key)
