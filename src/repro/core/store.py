"""The Store (paper §3.5): object-level interface over a Connector.

* (de)serializes Python objects / JAX pytrees (custom hooks registerable),
* caches *after deserialization* (paper: "to avoid duplicate deserializations"),
* ``proxy()`` / ``proxy_batch()`` produce transparent lazy proxies whose
  factories carry only ``(store config, key)``,
* object lifetimes are *reference counted* (the ownership subsystem,
  following arXiv:2407.01764): ``evict=True`` proxies are refcounted
  ephemerals (each sibling holds a reference, dropped on resolve; the key
  is evicted exactly once, after the LAST consumer — not on the first,
  which used to break every other consumer), ``owned_proxy()`` returns an
  :class:`~repro.core.OwnedProxy` whose reference is dropped on
  GC/release/context-exit, and ``lease()`` puts TTL bounds on keys so
  crashed reference holders can't leak them,
* ``resolve_async`` overlaps proxy resolution with compute,
* stores register globally by name: a proxy resolved on a process without the
  store re-materializes it from the factory's embedded config, and later
  proxies reuse the registered instance (shared caches, live connections).
"""
from __future__ import annotations

import pickle
import threading
import uuid as uuid_mod
import warnings
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

from repro.analysis import sanitize as _san
from repro.core.connector import (Connector, Key, import_path,
                                  resolve_import_path)
from repro.core.proxy import OwnedProxy, Proxy, get_factory, is_proxy
from repro.core.serialize import (deserialize, frame_nbytes, materialize,
                                  serialize)
from repro.stream.interface import StreamConsumer as _BrokerConsumer
from repro.stream.interface import StreamProducer as _BrokerProducer
from repro.stream.kv import KVBroker

_REGISTRY: dict[str, "Store"] = {}
_REGISTRY_LOCK = threading.RLock()
_RESOLVE_POOL: ThreadPoolExecutor | None = None
_POOL_LOCK = threading.Lock()


def _pool() -> ThreadPoolExecutor:
    global _RESOLVE_POOL
    with _POOL_LOCK:
        if _RESOLVE_POOL is None:
            _RESOLVE_POOL = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="psj-resolve")
        return _RESOLVE_POOL


class _LRUCache:
    def __init__(self, maxsize: int) -> None:
        self.maxsize = maxsize
        self._data: OrderedDict[Key, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Key, default=None):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return default

    def put(self, key: Key, value: Any) -> None:
        if self.maxsize <= 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def pop(self, key: Key) -> None:
        with self._lock:
            self._data.pop(key, None)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


class _RaisedException:
    """Stored in place of a result by :meth:`ProxyFuture.set_exception` (or
    a stream producer's ``append_exception``): every consumer that resolves
    the key re-raises the producer's pickled error instead of receiving a
    value.  The exception is pickled eagerly so a producer-side object that
    cannot transit degrades to a described RuntimeError, not a late
    serializer crash in some consumer."""

    __slots__ = ("blob", "text")

    def __init__(self, exc: BaseException) -> None:
        self.text = f"{type(exc).__name__}: {exc}"
        try:
            self.blob = pickle.dumps(exc)
        except Exception:  # noqa: BLE001 - unpicklable producer error
            self.blob = None

    def unwrap(self) -> BaseException:
        if self.blob is not None:
            try:
                return pickle.loads(self.blob)
            except Exception:  # noqa: BLE001 - consumer missing the class
                pass
        return RuntimeError(f"remote producer failed: {self.text}")


@dataclass
class StoreConfig:
    name: str
    connector_path: str
    connector_config: dict[str, Any]
    cache_size: int = 16

    @classmethod
    def fabric(cls, name: str, shards: Sequence, *, replication: int = 2,
               quorum: bool = False, op_timeout: float = 10.0,
               cache_size: int = 16) -> "StoreConfig":
        """Config for a store over the sharded KV fabric: ``shards`` are
        ``host:port`` / ``unix:/path`` addresses; see
        :class:`repro.core.fabric.ShardedConnector` for replication and
        failover semantics.  The config (and every proxy minted from the
        store) is location-free — any process rebuilds the same ring."""
        return cls(name=name,
                   connector_path="repro.core.fabric:ShardedConnector",
                   connector_config={"shards": [str(s) for s in shards],
                                     "replication": replication,
                                     "quorum": quorum,
                                     "op_timeout": op_timeout},
                   cache_size=cache_size)

    def build(self) -> "Store":
        cls = resolve_import_path(self.connector_path)
        connector = cls(**self.connector_config)
        try:
            return Store(self.name, connector, cache_size=self.cache_size)
        except BaseException:
            # we own this connector: a failed Store() (e.g. duplicate-name
            # registration) must not leak its sockets/servers/segments
            try:
                connector.close()
            except Exception:  # noqa: BLE001 - preserve the original error
                pass
            raise


@dataclass
class StoreFactory:
    """Callable that retrieves ``key`` from the named store.

    Self-contained (paper §3.3): includes everything needed to re-create the
    Store on any process.  Supports async pre-resolution via ``resolve_async``
    (the Future intentionally does not survive pickling).

    Lifetime semantics (the ownership subsystem):

    * ``evict=True`` — a *refcounted ephemeral*: the factory holds one
      reference to the key (acquired by ``Store.proxy(..., evict=True)``)
      and decrefs it after a successful resolve; the store evicts the key
      only when the LAST sibling's reference is dropped.  Pickling an
      unconsumed factory acquires a reference for the communicated sibling,
      so any number of consumers across processes resolve safely — this
      replaces the old fire-and-forget hard evict, whose first resolve
      broke every other consumer.
    * ``owned=True`` — the factory backs an :class:`~repro.core.OwnedProxy`:
      the reference is dropped by ``release()`` (GC/context-manager/explicit)
      rather than on resolve, and pickling clones a reference for the copy.
    * neither — a plain proxy: no lifetime bookkeeping at all.

    ``wait_timeout`` marks a *pre-data* factory (minted by
    :meth:`Store.future` before the object exists): resolution blocks in
    the connector's ``wait`` until the producer lands the payload — the
    distributed-future pattern of arXiv:2407.01764.
    """

    key: Key
    store_config: StoreConfig
    evict: bool = False
    owned: bool = False
    wait_timeout: float | None = None
    _future: Future | None = field(default=None, repr=False, compare=False)
    _spent: bool = field(default=False, repr=False, compare=False)
    _borrows: int = field(default=0, repr=False, compare=False)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def _store(self) -> "Store":
        return get_or_create_store(self.store_config)

    def __call__(self) -> Any:
        fut, self._future = self._future, None
        if fut is not None:
            return fut.result()
        return self._fetch()

    def peek(self) -> Any:
        """Fetch the object WITHOUT consuming a reference (borrowed access)."""
        store = self._store()
        if self.wait_timeout is not None:
            # pre-data proxy: park until the producer lands the payload
            # (or re-raise the producer's pickled exception)
            return store.wait_get(self.key, self.wait_timeout)
        obj = store.get(self.key)
        if obj is None and not store.exists(self.key):
            raise LookupError(
                f"key {self.key} not found in store {self.store_config.name!r}")
        return obj

    def _fetch(self) -> Any:
        obj = self.peek()
        if self.evict or self.owned:
            # this resolve's reference may be the key's LAST: on channels
            # whose gets return borrowed memory (shm arenas), detach the
            # object before the backing chunk can be recycled under it
            obj = self._store()._own_result(self.key, obj)
        if self.evict and not self.owned:
            self._spend()            # decref-on-resolve; evicts at zero
        return obj

    def _spend(self) -> None:
        """Drop this factory's reference exactly once (thread-safe)."""
        with self._lock:
            if self._spent:
                return
            self._spent = True
        try:
            self._store().decref(self.key)
        except (ConnectionError, OSError):
            pass     # channel gone: the key's lease is the cleanup backstop

    # -- the lifetime protocol consumed by proxy.OwnedProxy/borrow/clone ----
    def release(self) -> None:
        """Drop an owned reference (OwnedProxy finalizer / explicit)."""
        with self._lock:
            if self._spent:
                return
            if self._borrows > 0:
                raise RuntimeError(
                    f"{self._borrows} borrowed prox(ies) still alive")
            self._spent = True
        try:
            self._store().decref(self.key)
        except (ConnectionError, OSError):
            pass

    def active_borrows(self) -> int:
        return self._borrows

    def add_borrow(self) -> None:
        with self._lock:
            if self._spent:
                raise RuntimeError("cannot borrow a released proxy")
            self._borrows += 1

    def drop_borrow(self) -> None:
        with self._lock:
            self._borrows = max(0, self._borrows - 1)

    def clone(self) -> "StoreFactory":
        """Acquire one more reference; a factory for a co-owning proxy."""
        with self._lock:
            if self._spent:
                # incref-ing a key whose last reference may already have
                # evicted it would create a phantom count on dead data
                raise RuntimeError(
                    "cannot clone a released or consumed proxy reference")
            # incref under the lock: a racing release() cannot drop the
            # last reference between the check and the acquisition
            self._store().incref(self.key)
        return StoreFactory(key=self.key, store_config=self.store_config,
                            owned=True)

    def into_owned(self) -> "StoreFactory":
        """Owning factory for this key.  An unconsumed ``evict=True``
        factory MOVES its pending reference (it will no longer decref on
        resolve); a plain factory acquires a fresh reference; an already
        consumed/released factory raises (its claim on the key is gone)."""
        if self.evict and not self.owned:
            with self._lock:
                if not self._spent:
                    self._spent = True   # steal the resolve-time reference
                    return StoreFactory(key=self.key,
                                        store_config=self.store_config,
                                        owned=True)
        return self.clone()

    def detached(self) -> "StoreFactory":
        """Plain non-owning factory for the same key (pickled borrows)."""
        return StoreFactory(key=self.key, store_config=self.store_config)

    def resolve_async(self) -> None:
        if self._future is None:
            self._future = _pool().submit(self._fetch)

    def __getstate__(self):
        state = self.__dict__.copy()
        state["_future"] = None
        state["_borrows"] = 0
        state.pop("_lock", None)
        # increfs happen under the lock so a racing release()/resolve
        # cannot drop the last reference between the check and acquisition
        with self._lock:
            if self.owned:
                if self._spent:
                    raise RuntimeError("cannot pickle a released OwnedProxy")
                # clone-on-pickle: the communicated copy owns its own ref
                self._store()._incref_transfer(self.key)
            elif self.evict:
                if self._spent:
                    state["evict"] = False   # reference already consumed
                else:
                    # the communicated sibling carries its own reference,
                    # so N consumers across processes all resolve and the
                    # key dies exactly once, after the last of them
                    self._store()._incref_transfer(self.key)
        state["_spent"] = False
        return state

    def __setstate__(self, state):
        state["_lock"] = threading.Lock()
        state.setdefault("_future", None)
        state.setdefault("wait_timeout", None)
        self.__dict__.update(state)


class Store:
    def __init__(self, name: str, connector: Connector, *,
                 cache_size: int = 16,
                 serializer: Callable[[Any], bytes] | None = None,
                 deserializer: Callable[[bytes], Any] | None = None,
                 register: bool = True,
                 sanitize: bool | None = None) -> None:
        self.name = name
        self.connector = connector
        # register FIRST: a duplicate name must fail before this instance
        # builds any further state (and StoreConfig.build closes the
        # connector it constructed when this raises)
        if register:
            register_store(self)
        self._serialize = serializer or serialize
        self._deserialize = deserializer or deserialize
        self.cache = _LRUCache(cache_size)
        self.cache_size = cache_size
        self.sanitize = _san.enabled() if sanitize is None else bool(sanitize)
        self._ledger = _san.RefLedger(name) if self.sanitize else None
        if self.sanitize:
            enable = getattr(connector, "enable_sanitizer", None)
            if callable(enable):
                enable()

    # -- config round trip -----------------------------------------------------
    def config(self) -> StoreConfig:
        return StoreConfig(
            name=self.name,
            connector_path=import_path(type(self.connector)),
            connector_config=self.connector.config(),
            cache_size=self.cache_size,
        )

    # -- object ops --------------------------------------------------------------
    def put(self, obj: Any, **kwargs) -> Key:
        return self.connector.put(self._serialize(obj), **kwargs) \
            if kwargs else self.connector.put(self._serialize(obj))

    def put_batch(self, objs: Sequence[Any]) -> list[Key]:
        return self.connector.put_batch([self._serialize(o) for o in objs])

    def get(self, key: Key, default: Any = None) -> Any:
        key = tuple(key)
        cached = self.cache.get(key, _MISS)
        if cached is not _MISS:
            if isinstance(cached, _RaisedException):
                raise cached.unwrap()   # a failed future's key: re-raise
            return cached
        blob = self.connector.get(key)
        if blob is None:
            return default
        obj = self._deserialize(blob)
        self.cache.put(key, obj)  # cache post-deserialization (paper §3.5)
        if isinstance(obj, _RaisedException):
            raise obj.unwrap()
        return obj

    def _own_result(self, key: Key, obj: Any) -> Any:
        """Detach ``obj`` from borrowed channel memory (deep-copying array
        views) and refresh the cache so every later hit serves the owned
        copy.  No-op (zero-copy preserved) on channels whose gets return
        fresh or immutable buffers."""
        if not getattr(self.connector, "borrows_get", False):
            return obj
        owned = materialize(obj)
        if owned is not None:
            # never cache None: an exists-but-unreadable-this-instant miss
            # must not poison later resolves of the (live) key
            self.cache.put(tuple(key), owned)
        return owned

    def get_batch(self, keys: Sequence[Key], default: Any = None, *,
                  strict: bool = False,
                  _raise_failures: bool = True) -> list[Any]:
        """Fetch many objects in ONE batched connector exchange.

        Cache hits are served locally; the misses go through
        ``connector.get_batch`` (a single pipelined ``mget2`` on KV-backed
        connectors) and are deserialized + cached like ``get``.

        ``strict=True`` applies the same miss check as the scalar proxy
        path (``peek``): keys the channel no longer holds raise
        ``LookupError`` (ONE batched exists exchange for all unresolved
        keys) instead of being silently filled with ``default``.

        A key holding a failed future's pickled error re-raises it like
        ``get``/``wait_get`` do (``_raise_failures=False`` is the internal
        group-resolve path, which delivers each error to its own proxy).
        """
        keys = [tuple(k) for k in keys]
        out: list[Any] = [default] * len(keys)
        miss_idx: list[int] = []
        unresolved: list[int] = []
        for i, k in enumerate(keys):
            cached = self.cache.get(k, _MISS)
            if cached is not _MISS:
                out[i] = cached
            else:
                miss_idx.append(i)
        if miss_idx:
            blobs = self.connector.get_batch([keys[i] for i in miss_idx])
            for i, blob in zip(miss_idx, blobs):
                if blob is None:
                    unresolved.append(i)
                    continue
                obj = self._deserialize(blob)
                self.cache.put(keys[i], obj)
                out[i] = obj
        if _raise_failures:
            for obj in out:
                if isinstance(obj, _RaisedException):
                    raise obj.unwrap()
        if strict and unresolved:
            flags = self.connector.exists_batch(
                [keys[i] for i in unresolved])
            missing = [keys[i] for i, ok in zip(unresolved, flags) if not ok]
            for k in missing:
                self.cache.pop(k)   # a dead key must not stale-serve later
            if missing:
                raise LookupError(
                    f"keys not found in store {self.name!r}: {missing}")
        return out

    # -- block-granular reservation (KV-cache paging data plane) -------------
    def reserve_block(self, nbytes: int, *,
                      ttl: float | None = None) -> tuple[Key, memoryview]:
        """Reserve ``nbytes`` of channel memory and return ``(key, view)``:
        the caller writes the payload straight into ``view`` (no serializer,
        no staging copy) and publishes with :meth:`commit_block`.  ``ttl``
        puts a lease on the key as a crashed-producer backstop.  Only
        channels with ``supports_blocks`` (the shm arena) implement this.
        """
        key, view = self.connector.reserve_block(nbytes)
        key = tuple(key)
        if ttl is not None:
            self.connector.touch(key, ttl)
        return key, view

    def commit_block(self, key: Key) -> None:
        """Publish a reserved block (atomic commit-byte store)."""
        self.connector.commit_block(tuple(key))

    def block_view(self, key: Key):
        """Raw bytes-like payload of ``key`` — NO deserialization and NO
        caching: the path for fixed-layout blocks the caller reinterprets
        itself (``np.frombuffer``).  Returns None when the key is gone.
        Contents of a returned view are only stable while the key is
        pinned (refcount/lease)."""
        return self.connector.get(tuple(key))

    def sweep_leases(self) -> int:
        """Expire overdue leases now; returns the number of keys
        reclaimed.  The explicit memory-pressure hook (lazy expiry already
        rides every lifecycle op)."""
        sweep = getattr(self.connector, "sweep_leases", None)
        return int(sweep()) if callable(sweep) else 0

    # -- futures: communicate data before it exists -------------------------
    def put_to(self, key: Key, obj: Any) -> None:
        """Serialize + store under a key minted by ``connector.reserve()``
        (the produce side of a :class:`ProxyFuture`)."""
        self.connector.put_to(tuple(key), self._serialize(obj))

    def wait_get(self, key: Key, timeout: float = 60.0) -> Any:
        """Blocking get for data that may not exist yet: parks in the
        connector's ``wait`` until a producer lands the key (TimeoutError
        otherwise).  A payload stored by ``set_exception`` re-raises the
        producer's error."""
        key = tuple(key)
        obj = self.cache.get(key, _MISS)
        if obj is _MISS:
            blob = self.connector.wait(key, timeout)
            obj = self._deserialize(blob)
            self.cache.put(key, obj)   # every waiter sees the same outcome
        if isinstance(obj, _RaisedException):
            raise obj.unwrap()
        return obj

    def future(self, *, timeout: float = 60.0,
               ttl: float | None = None) -> "ProxyFuture":
        """Mint a :class:`ProxyFuture`: a key with no data behind it whose
        ``.proxy()`` is a valid pre-data proxy (consumers may be dispatched
        — even to other processes/sites — before the object exists; their
        resolve parks in ``wait``).  ``set_result`` publishes the object;
        ``set_exception`` propagates the producer's pickled error to every
        waiter.  ``ttl`` leases the eventual payload as a leak backstop."""
        return ProxyFuture(self, self.connector.reserve(),
                           timeout=timeout, ttl=ttl)

    # -- streams: broker-backed per-topic pub/sub ----------------------------
    def stream_producer(self, topic: str | None = None, *,
                        ttl: float | None = None, limit: int | None = None,
                        timeout: float | None = None) -> "StreamProducer":
        """Producer handle for an ordered stream of objects.  Items are
        appended as they are produced (no barrier) and stored refcounted —
        one reference per subscribed consumer group (the last group's ack
        evicts; a lone default-group consumer keeps the classic evicted-
        exactly-once behavior).  ``ttl`` leases items against abandoned
        streams; ``limit`` installs credit-based backpressure (appends
        park once ``limit`` events sit unacked, TimeoutError past
        ``timeout``)."""
        return StreamProducer(self, topic or f"s-{uuid_mod.uuid4().hex}",
                              ttl=ttl, limit=limit, timeout=timeout)

    def stream_consumer(self, topic: str, *, timeout: float = 60.0,
                        prefetch: int = 8, location: str | None = None,
                        group: str = "default", start: str = "begin",
                        filter: dict | None = None,  # noqa: A002
                        payload: bool = True) -> "ProxyStream":
        """Iterator over a topic's objects for one consumer ``group``:
        blocks for the next event (released by the producer's append,
        ends at ``close``), then batch-prefetches the already-deliverable
        tail in ONE exchange.  Every group sees every event its
        server-side ``filter`` matches, with payload bytes crossing the
        data plane once regardless of how many groups subscribe;
        ``payload=False`` subscribes a metadata-only tap.  ``location``
        addresses the producing site on location-addressed channels
        (socket node ids, PS-endpoint uuids) — connectors without
        location addressing reject it with ``ValueError``."""
        return ProxyStream(self, topic, timeout=timeout, prefetch=prefetch,
                           location=location, group=group, start=start,
                           filter=filter, payload=payload)

    # -- future-returning async ops ---------------------------------------------
    def put_async(self, obj: Any) -> Future:
        """Serialize + store off-thread; ``Future[Key]``.  Many in-flight
        puts share the connector's pipelined connection."""
        return _pool().submit(self.put, obj)

    def get_async(self, key: Key, default: Any = None) -> Future:
        """Fetch + deserialize off-thread; ``Future[Any]``."""
        return _pool().submit(self.get, key, default)

    def exists(self, key: Key) -> bool:
        key = tuple(key)
        if self.connector.exists(key):
            return True
        # the key is gone on the channel (evicted — possibly by another
        # consumer's decref): drop any stale deserialization-cache entry so
        # a local hit can't report a dead key as alive
        self.cache.pop(key)
        return False

    def evict(self, key: Key) -> None:
        key = tuple(key)
        self.cache.pop(key)
        self.connector.evict(key)
        # explicit evict is an override: lifecycle state dies with the
        # data (server-backed connectors do this in their _evict; local
        # fallback tables need the nudge)
        forget = getattr(self.connector, "_forget_lifetime", None)
        if forget is not None:
            forget(key)

    # -- lifecycle: refcounts + leases -------------------------------------------
    def incref(self, key: Key, n: int = 1) -> int:
        """Add ``n`` references to ``key``; returns the new count."""
        key = tuple(key)
        if self._ledger is not None:
            self._ledger.incref(key, n)
        return int(self.connector.incref(key, n))

    def _incref_transfer(self, key: Key, n: int = 1) -> int:
        """Incref on behalf of a pickled sibling: the reference travels
        with the bytes and is released by whoever unpickles them."""
        key = tuple(key)
        if self._ledger is not None:
            self._ledger.incref(key, n, transfer=True)
        return int(self.connector.incref(key, n))

    def decref(self, key: Key, n: int = 1) -> int:
        """Drop ``n`` references; the connector evicts the key (exactly
        once) when the count reaches zero."""
        key = tuple(key)
        if self._ledger is not None:
            self._ledger.decref(key, n)   # raises double-decref pre-channel
        count = int(self.connector.decref(key, n))
        if count <= 0:
            self.cache.pop(key)
            if self._ledger is not None:
                self._ledger.mark_dead(key)
        return count

    def refcount(self, key: Key) -> int:
        return int(self.connector.refcount(tuple(key)))

    def lease(self, key: Key, ttl: float | None) -> bool:
        """Set/refresh a TTL lease on ``key`` (``None``/<=0 clears it): the
        channel evicts the key once the lease expires without a refresh,
        bounding leaks from reference holders that died.  Returns whether
        the key currently exists."""
        return bool(self.connector.touch(tuple(key), ttl))

    # -- the proxy interface -----------------------------------------------------
    def proxy(self, obj: Any, evict: bool = False,
              ttl: float | None = None) -> Proxy:
        key = self.put(obj)
        return self.proxy_from_key(key, evict=evict, ttl=ttl)

    def proxy_from_key(self, key: Key, evict: bool = False,
                       ttl: float | None = None) -> Proxy:
        key = tuple(key)
        if evict:
            # refcounted ephemeral: this sibling holds one reference,
            # dropped on resolve — the key dies after the LAST consumer
            self.incref(key)
        if ttl is not None:
            # lease backstop: a pickled-but-never-delivered sibling (or a
            # consumer that dies before resolving) cannot leak the key
            self.connector.touch(key, ttl)
        return Proxy(StoreFactory(key=key, store_config=self.config(),
                                  evict=evict))

    def proxy_batch(self, objs: Sequence[Any], evict: bool = False,
                    ttl: float | None = None) -> list[Proxy]:
        keys = self.put_batch(objs)  # single batch op (e.g. one Globus task)
        if evict:
            if self._ledger is not None:
                for k in keys:
                    self._ledger.incref(tuple(k))
            self.connector.incref_batch([tuple(k) for k in keys])  # one exchange
        if ttl is not None:
            self.connector.touch_batch([tuple(k) for k in keys], ttl)
        if evict:
            config = self.config()
            return [Proxy(StoreFactory(key=tuple(k), store_config=config,
                                       evict=True)) for k in keys]
        return [self.proxy_from_key(k) for k in keys]

    def owned_proxy(self, obj: Any, ttl: float | None = None) -> OwnedProxy:
        """Proxy ``obj`` with an OWNED lifetime: the returned
        :class:`OwnedProxy` holds one reference, dropped when it is
        garbage-collected, released, or exits its ``with`` block — at zero
        references the key is evicted.  ``ttl`` additionally puts a lease
        on the key as a crash backstop."""
        return self.owned_proxy_from_key(self.put(obj), ttl=ttl)

    def owned_proxy_from_key(self, key: Key,
                             ttl: float | None = None) -> OwnedProxy:
        key = tuple(key)
        self.incref(key)
        if ttl is not None:
            self.connector.touch(key, ttl)
        return OwnedProxy(StoreFactory(key=key, store_config=self.config(),
                                       owned=True))

    # -- perf counters -----------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Perf counters: LRU cache hits/misses plus connector/server stats
        where the connector exposes them (KV-backed connectors report the
        server's object count / byte total / op count)."""
        out: dict[str, Any] = {
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_len": len(self.cache),
            "cache_maxsize": self.cache.maxsize,
        }
        conn_stats = getattr(self.connector, "stats", None)
        if callable(conn_stats):
            try:
                out["connector"] = conn_stats()
            except (ConnectionError, OSError):  # server gone: counters only
                out["connector"] = None
        return out

    def close(self, *, close_connector: bool = True) -> None:
        if self._ledger is not None:
            self._report_leaks()
        unregister_store(self.name)
        if close_connector:
            self.connector.close()

    def _report_leaks(self) -> None:
        """Cross-check the ledger's leak candidates against server counts
        and warn (non-fatally) about confirmed unreleased references."""
        confirmed = []
        for key, balance, site in self._ledger.leak_candidates():
            try:
                server = int(self.connector.refcount(key))
            except Exception:  # noqa: BLE001 - channel gone: cannot confirm
                continue
            if server > 0:
                confirmed.append((key, balance, server, site))
        if confirmed:
            warnings.warn(self._ledger.format_leaks(confirmed),
                          _san.SanitizerWarning, stacklevel=3)

    def __repr__(self) -> str:
        return f"Store(name={self.name!r}, connector={type(self.connector).__name__})"


_MISS = object()


# ---------------------------------------------------------------------------
# futures + streams (arXiv:2407.01764 patterns two and three)
# ---------------------------------------------------------------------------
class ProxyFuture:
    """A slot for an object that does not exist yet.

    ``proxy()`` returns a valid *pre-data* :class:`Proxy` — small, picklable,
    dispatchable to consumers anywhere — whose resolve parks in the
    channel's ``wait`` until the producer calls :meth:`set_result` (or
    re-raises the pickled error from :meth:`set_exception`).  This is what
    lets a producer communicate data *unilaterally*: consumers are in
    flight before the object is computed, and the transfer overlaps the
    producer's remaining work.
    """

    def __init__(self, store: Store, key: Key, *, timeout: float = 60.0,
                 ttl: float | None = None) -> None:
        self._store = store
        self.key = tuple(key)
        self.timeout = timeout
        self.ttl = ttl
        self._completed = False
        self._lock = threading.Lock()

    def proxy(self, timeout: float | None = None) -> Proxy:
        """A pre-data proxy of the eventual object (resolve blocks up to
        ``timeout`` — default: this future's — in the channel's wait)."""
        return Proxy(StoreFactory(
            key=self.key, store_config=self._store.config(),
            wait_timeout=self.timeout if timeout is None else timeout))

    def _complete(self, payload: Any) -> None:
        with self._lock:
            if self._completed:
                raise RuntimeError(f"future {self.key} is already set")
            self._completed = True
        # waiter wakeup belongs to put_to: server-backed channels wake
        # parked waiters when the put lands, fallback put_to announces
        self._store.put_to(self.key, payload)
        if self.ttl is not None:
            self._store.connector.touch(self.key, self.ttl)

    def set_result(self, obj: Any) -> None:
        """Publish the object: every parked consumer resolves."""
        self._complete(obj)

    def set_exception(self, exc: BaseException) -> None:
        """Publish a failure: every parked consumer (and any later one)
        re-raises the pickled error."""
        self._complete(_RaisedException(exc))

    def done(self) -> bool:
        return self._completed or self._store.exists(self.key)

    def result(self, timeout: float | None = None) -> Any:
        """Consume locally: block until produced (TimeoutError otherwise)."""
        return self._store.wait_get(
            self.key, self.timeout if timeout is None else timeout)


class StreamProducer(_BrokerProducer):
    """Producer side of an ordered stream of objects (pattern three of
    arXiv:2407.01764): append as you produce, close when done.  Consumers
    (:class:`ProxyStream`) overlap with production — no barrier-put.

    A thin shim over :class:`repro.stream.StreamProducer` on the in-tree
    KV broker (the connector's ``stream_*`` ops): objects serialize
    through the Store and publish with an optional metadata map consumer
    groups filter on.  Usable as a context manager: the stream closes on
    exit, so consumers observe end-of-stream instead of timing out.
    """

    def __init__(self, store: Store, topic: str, ttl: float | None = None,
                 *, limit: int | None = None,
                 timeout: float | None = None) -> None:
        self._store = store
        super().__init__(KVBroker(store.connector), topic,
                         serializer=store._serialize, ttl=ttl,
                         limit=limit, timeout=timeout)

    def append_exception(self, exc: BaseException,
                         *, meta: dict | None = None) -> int:
        """Append a failure marker: the consumer re-raises it in order."""
        return self.append(_RaisedException(exc), meta=meta)

    @property
    def location(self) -> str | None:
        """Producing site id for location-addressed channels (the value a
        remote consumer passes as ``stream_consumer(location=...)``)."""
        conn = self._store.connector
        return getattr(conn, "endpoint_uuid", None) or (
            getattr(conn, "node_id", None) if conn.supports_location
            else None)


class ProxyStream(_BrokerConsumer):
    """Consumer side: an iterator yielding a topic's objects in order,
    as one named consumer group on the broker-backed stream plane.

    ``__next__`` parks in the broker's group take until the next matching
    event is published (StopIteration once the producer closes past it);
    when the producer is ahead, the already-deliverable tail is
    prefetched in ONE batched exchange, so a fast consumer pays one round
    trip per *batch*, not per item.  With the default lone group the
    classic semantics hold: each object is delivered exactly once and
    evicted after its delivery is acked.  With several groups every group
    gets every matching object, and the payload is evicted after the LAST
    group's ack — the bytes still cross the data plane once per
    delivering group, never per subscriber re-publish.

    Prefetched events stay unacked until actually yielded, so
    :meth:`close` hands anything prefetched-but-undelivered back to the
    group instead of leaking it.  Producer exceptions
    (:meth:`StreamProducer.append_exception`) re-raise in order.
    """

    def __init__(self, store: Store, topic: str, *, timeout: float = 60.0,
                 prefetch: int = 8, location: str | None = None,
                 group: str = "default", start: str = "begin",
                 filter: dict | None = None,  # noqa: A002
                 payload: bool = True) -> None:
        self._store = store
        self.location = location
        super().__init__(KVBroker(store.connector, location=location),
                         topic, group, start=start, filter=filter,
                         payload=payload, prefetch=prefetch,
                         timeout=timeout, deserializer=self._materialize)

    def _materialize(self, blob) -> Any:
        obj = self._store._deserialize(blob)
        if isinstance(obj, _RaisedException):
            raise obj.unwrap()
        return obj


# ---------------------------------------------------------------------------
# global registry (paper §3.5)
# ---------------------------------------------------------------------------
def register_store(store: Store) -> None:
    with _REGISTRY_LOCK:
        existing = _REGISTRY.get(store.name)
        if existing is not None and existing is not store:
            raise ValueError(f"store {store.name!r} already registered")
        _REGISTRY[store.name] = store


def unregister_store(name: str) -> None:
    with _REGISTRY_LOCK:
        _REGISTRY.pop(name, None)


def get_store(name: str) -> Store | None:
    with _REGISTRY_LOCK:
        return _REGISTRY.get(name)


def get_or_create_store(config: StoreConfig) -> Store:
    with _REGISTRY_LOCK:
        store = _REGISTRY.get(config.name)
        if store is None:
            store = config.build()  # Store() self-registers
        return store


# ---------------------------------------------------------------------------
# proxy helpers
# ---------------------------------------------------------------------------
def _fetch_group(config: StoreConfig, factories: list[StoreFactory],
                 futures: list[Future]) -> None:
    """Resolve a same-store batch of factories with ONE connector exchange.

    Misses get the same loud treatment as the scalar path's ``peek``:
    unresolved keys go through ONE batched exists check, and each proxy of
    a key the channel no longer holds fails with ``LookupError`` (only
    those proxies — siblings of *other* keys in the batch still resolve).
    The ``_MISS`` sentinel keeps a legitimately-stored ``None`` value
    distinct from an evicted key.
    """
    try:
        store = get_or_create_store(config)
        keys = [f.key for f in factories]
        objs = store.get_batch(keys, default=_MISS, _raise_failures=False)
        miss = [i for i, o in enumerate(objs) if o is _MISS]
        flags = (store.connector.exists_batch([keys[i] for i in miss])
                 if miss else [])
        exists_now = {i: bool(ok) for i, ok in zip(miss, flags)}
        for i, (factory, fut, obj) in enumerate(
                zip(factories, futures, objs)):
            if fut.done():
                continue
            if obj is _MISS:
                if not exists_now.get(i):
                    store.cache.pop(factory.key)   # no stale-serving later
                    fut.set_exception(LookupError(
                        f"key {factory.key} not found in store "
                        f"{config.name!r}"))
                    continue
                obj = None   # exists but unreadable this instant: mirror
                # the scalar path, which also returns None here
            if isinstance(obj, _RaisedException):
                # a failed future's key: ONLY this key's proxies get the
                # producer's error; siblings of other keys still resolve
                fut.set_exception(obj.unwrap())
                continue
            if factory.evict or factory.owned:
                # mirror the scalar path: detach from borrowed channel
                # memory before this sibling's reference is dropped
                obj = store._own_result(factory.key, obj)
            if factory.evict and not factory.owned:
                factory._spend()     # drop this sibling's reference
            fut.set_result(obj)
    except BaseException as e:  # noqa: BLE001 - deliver into the futures
        for fut in futures:
            if not fut.done():
                fut.set_exception(e)


def resolve_async(proxy: "Proxy | Sequence[Proxy]") -> None:
    """Begin resolving proxies in the background (paper §3.5).

    Accepts one proxy or a sequence.  Batches are grouped by store, and
    each group is fetched with a single ``Store.get_batch`` — on KV-backed
    connectors that is ONE pipelined ``mget2`` round trip for the whole
    batch, overlapped with the caller's compute.
    """
    proxies = [proxy] if is_proxy(proxy) else list(proxy)
    groups: dict[str, list[StoreFactory]] = {}
    for p in proxies:
        factory = get_factory(p)
        if not (isinstance(factory, StoreFactory)
                and factory._future is None):
            continue
        if factory.wait_timeout is not None:
            # pre-data future proxy: it must PARK in wait, not ride the
            # batch mget (whose miss check would raise LookupError for a
            # key the producer simply hasn't landed yet)
            factory.resolve_async()
            continue
        groups.setdefault(factory.store_config.name, []).append(factory)
    for factories in groups.values():
        if len(factories) == 1:
            factories[0].resolve_async()
            continue
        futures: list[Future] = [Future() for _ in factories]
        for factory, fut in zip(factories, futures):
            factory._future = fut
        _pool().submit(_fetch_group, factories[0].store_config, factories,
                       futures)


def maybe_proxy(store: Store, obj: Any, threshold_bytes: int = 0) -> Any:
    """Proxy ``obj`` through ``store`` if it serializes above the threshold.

    The Colmena-integration pattern (§5.2): small objects ride the control
    plane, large ones go by proxy.
    """
    if is_proxy(obj):
        return obj
    # The store's *configured* serializer decides size and produces the
    # stored blob — a custom serializer= must see the same bytes its
    # deserializer= will get back, and we serialize exactly once.
    blob = store._serialize(obj)
    if frame_nbytes(blob) < threshold_bytes:
        return obj
    key = store.connector.put(blob)
    return store.proxy_from_key(key)
