"""MultiConnector — policy-routed composition of connectors (paper §4.3).

Initialized with ``[(connector, Policy), ...]``; every ``put`` is matched
against each policy (size bounds, site tags, arbitrary constraint tags) and
routed to the highest-priority connector that accepts.  ``get``/``exists``/
``evict`` dispatch on the key, which records which child connector stored the
object.  If nothing matches, an error is raised unless a fallback (policy
with no constraints) is configured — mirroring the paper's guidance.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.core.connector import (BaseConnector, Connector, Key,
                                  group_indices, import_path,
                                  resolve_import_path)
from repro.core.serialize import frame_nbytes

log = logging.getLogger(__name__)


class NoConnectorMatch(RuntimeError):
    pass


@dataclass
class Policy:
    min_size: int = 0
    max_size: int | None = None          # bytes; None = unbounded
    tags: frozenset = frozenset()         # sites/capabilities this connector serves
    priority: int = 0                     # higher wins among matches

    def accepts(self, size: int, constraints: frozenset) -> bool:
        if size < self.min_size:
            return False
        if self.max_size is not None and size > self.max_size:
            return False
        # every requested constraint must be offered by this connector
        return constraints <= self.tags if constraints else True

    def to_dict(self) -> dict[str, Any]:
        return {"min_size": self.min_size, "max_size": self.max_size,
                "tags": sorted(self.tags), "priority": self.priority}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Policy":
        return cls(min_size=d.get("min_size", 0), max_size=d.get("max_size"),
                   tags=frozenset(d.get("tags", ())),
                   priority=d.get("priority", 0))


class MultiConnector(BaseConnector):
    def __init__(self, connectors: Sequence[tuple[Connector, Policy]] | None = None,
                 *, _config: list[dict] | None = None) -> None:
        if connectors is None and _config is not None:
            connectors = [
                (resolve_import_path(c["path"])(**c["config"]),
                 Policy.from_dict(c["policy"]))
                for c in _config
            ]
        if not connectors:
            raise ValueError(
                "MultiConnector requires at least one (connector, policy) "
                "pair — pass connectors=[...] or _config=[...]")
        self.children: list[tuple[Connector, Policy]] = list(connectors)
        # stable ids for key dispatch
        self._by_id = {i: conn for i, (conn, _) in enumerate(self.children)}

    @property
    def borrows_get(self) -> bool:
        """Borrowed-memory gets if ANY child borrows (routing is per-key,
        so a caller that must detach results has to assume the worst)."""
        return any(getattr(conn, "borrows_get", False)
                   for conn, _ in self.children)

    def _route_all(self, size: int,
                   constraints: frozenset) -> list[tuple[int, Connector]]:
        """Every policy-matching child, best first (priority desc, ties
        keep declaration order) — the put fall-through chain."""
        matches = [(-policy.priority, i, conn)
                   for i, (conn, policy) in enumerate(self.children)
                   if policy.accepts(size, constraints)]
        if not matches:
            raise NoConnectorMatch(
                f"no connector accepts size={size} constraints={set(constraints)}")
        matches.sort()
        return [(i, conn) for _, i, conn in matches]

    def _route(self, size: int, constraints: frozenset) -> tuple[int, Connector]:
        return self._route_all(size, constraints)[0]

    # -- ops -------------------------------------------------------------------
    def put(self, blob, constraints: Sequence[str] = ()) -> Key:
        # graceful degradation: a dead child (ConnectionError) must not
        # abort the put — fall through to the next policy match, loudly
        last: ConnectionError | None = None
        for idx, conn in self._route_all(frame_nbytes(blob),
                                         frozenset(constraints)):
            try:
                sub = conn.put(blob)
            except ConnectionError as e:
                log.error("multi: put failed on child %d (%s): %s; "
                          "falling through", idx, type(conn).__name__, e)
                last = e
                continue
            return ("multi", idx) + tuple(sub)
        raise last  # type: ignore[misc]  # every matching child refused

    def put_batch(self, blobs, constraints: Sequence[str] = ()) -> list[Key]:
        # route per-blob but batch per-child; a child failing its batch
        # falls through to the next match for just those blobs
        keys: list[Key] = [None] * len(blobs)  # type: ignore[list-item]
        failed: set[int] = set()
        pending = list(range(len(blobs)))
        last: ConnectionError | None = None
        while pending:
            routed: dict[int, list[int]] = {}
            for j in pending:
                for idx, _ in self._route_all(frame_nbytes(blobs[j]),
                                              frozenset(constraints)):
                    if idx not in failed:
                        routed.setdefault(idx, []).append(j)
                        break
                else:
                    raise last or NoConnectorMatch(
                        "every matching connector failed")
            pending = []
            for idx, js in routed.items():
                try:
                    subkeys = self._by_id[idx].put_batch(
                        [blobs[j] for j in js])
                except ConnectionError as e:
                    log.error("multi: put_batch failed on child %d: %s; "
                              "falling through (%d blobs)", idx, e, len(js))
                    failed.add(idx)
                    last = e
                    pending.extend(js)
                    continue
                for j, sk in zip(js, subkeys):
                    keys[j] = ("multi", idx) + tuple(sk)
        return keys

    def _child(self, key: Key) -> tuple[Connector, Key]:
        return self._by_id[key[1]], tuple(key[2:])

    def get(self, key: Key) -> bytes | None:
        conn, sub = self._child(key)
        return conn.get(sub)

    def _dispatch_batch(self, keys, method: str, *args) -> list:
        """Group keys by child and issue ONE batch op per child (each child
        then collapses its group into a single pipelined exchange)."""
        out: list = [None] * len(keys)
        for idx, js in group_indices(keys, 1).items():
            child = self._by_id[idx]
            results = getattr(child, method)(
                [tuple(keys[j][2:]) for j in js], *args)
            for j, r in zip(js, results or [None] * len(js)):
                out[j] = r
        return out

    def get_batch(self, keys) -> list[bytes | None]:
        return self._dispatch_batch(keys, "get_batch")

    def exists_batch(self, keys) -> list[bool]:
        return self._dispatch_batch(keys, "exists_batch")

    def evict_batch(self, keys) -> None:
        for idx, js in group_indices(keys, 1).items():
            self._by_id[idx].evict_batch([tuple(keys[j][2:]) for j in js])

    def exists(self, key: Key) -> bool:
        conn, sub = self._child(key)
        return conn.exists(sub)

    def evict(self, key: Key) -> None:
        conn, sub = self._child(key)
        conn.evict(sub)

    # -- futures + streams ---------------------------------------------------
    # Reserved keys are routed with size 0 (payload size is unknown before
    # the data exists — a policy that rejects small objects won't host
    # futures); wait/put_to then dispatch on the child the key records.
    # Stream ops go to the same deterministically-routed child on every
    # process rebuilt from this config, so producers and consumers meet.
    def _future_child(self) -> tuple[int, Connector]:
        return self._route(0, frozenset())

    def reserve(self) -> Key:
        idx, conn = self._future_child()
        return ("multi", idx) + tuple(conn.reserve())

    def put_to(self, key: Key, blob) -> None:
        conn, sub = self._child(key)
        conn.put_to(sub, blob)

    def announce(self, key: Key) -> None:
        conn, sub = self._child(key)
        conn.announce(sub)

    def wait(self, key: Key, timeout: float = 60.0):
        conn, sub = self._child(key)
        return conn.wait(sub, timeout)

    def stream_append(self, topic: str, blob, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        return self._future_child()[1].stream_append(topic, blob, ttl,
                                                     meta=meta,
                                                     timeout=timeout)

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    location: str | None = None):
        return self._future_child()[1].stream_next(topic, seq, timeout,
                                                   location)

    def stream_fetch(self, topic: str, seqs,
                     location: str | None = None) -> list:
        return self._future_child()[1].stream_fetch(topic, seqs, location)

    def stream_close(self, topic: str, location: str | None = None) -> None:
        self._future_child()[1].stream_close(topic, location)

    # pub/sub group ops ride the same deterministically-routed child (and
    # location addressing is whatever that child supports)
    @property
    def supports_location(self) -> bool:
        return bool(getattr(self._future_child()[1], "supports_location",
                            False))

    def stream_subscribe(self, topic: str, group: str, start: str = "new",
                         filter: dict | None = None,  # noqa: A002
                         location: str | None = None) -> dict:
        return self._future_child()[1].stream_subscribe(
            topic, group, start, filter, location)

    def stream_unsubscribe(self, topic: str, group: str,
                           location: str | None = None) -> None:
        self._future_child()[1].stream_unsubscribe(topic, group, location)

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True, location: str | None = None):
        return self._future_child()[1].stream_take(topic, group, timeout,
                                                   payload, location)

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True,
                          location: str | None = None) -> list:
        return self._future_child()[1].stream_take_batch(
            topic, group, n, payload, location)

    def stream_ack(self, topic: str, group: str, seqs,
                   location: str | None = None) -> int:
        return self._future_child()[1].stream_ack(topic, group, seqs,
                                                  location)

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None,
                       location: str | None = None) -> int:
        return self._future_child()[1].stream_requeue(
            topic, group, seqs, reason=reason, location=location)

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None,
                     location: str | None = None) -> None:
        self._future_child()[1].stream_limit(
            topic, limit, max_deliveries=max_deliveries, location=location)

    def stream_stat(self, topic: str,
                    location: str | None = None) -> dict:
        return self._future_child()[1].stream_stat(topic, location)

    # -- lifecycle: dispatch on the child that stored the object -------------
    def _forget_lifetime(self, key: Key) -> None:
        conn, sub = self._child(key)
        forget = getattr(conn, "_forget_lifetime", None)
        if forget is not None:
            forget(sub)

    def incref(self, key: Key, n: int = 1) -> int:
        conn, sub = self._child(key)
        return conn.incref(sub, n)

    def decref(self, key: Key, n: int = 1) -> int:
        conn, sub = self._child(key)
        return conn.decref(sub, n)

    def refcount(self, key: Key) -> int:
        conn, sub = self._child(key)
        return conn.refcount(sub)

    def touch(self, key: Key, ttl: float | None) -> bool:
        conn, sub = self._child(key)
        return conn.touch(sub, ttl)

    def incref_batch(self, keys, n: int = 1) -> list[int]:
        return self._dispatch_batch(keys, "incref_batch", n)

    def decref_batch(self, keys, n: int = 1) -> list[int]:
        return self._dispatch_batch(keys, "decref_batch", n)

    def touch_batch(self, keys, ttl: float | None) -> None:
        self._dispatch_batch(keys, "touch_batch", ttl)

    def stats(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for i, (conn, _) in enumerate(self.children):
            child_stats = getattr(conn, "stats", None)
            if callable(child_stats):
                out[f"{i}:{type(conn).__name__}"] = child_stats()
        return out

    def config(self) -> dict[str, Any]:
        return {"_config": [
            {"path": import_path(type(conn)), "config": conn.config(),
             "policy": policy.to_dict()}
            for conn, policy in self.children
        ]}

    def close(self) -> None:
        for conn, _ in self.children:
            conn.close()
