"""Sharded KV fabric: N ``KVServer`` shards behaving as ONE store.

``ShardedConnector`` presents the full :class:`~repro.core.connector.
Connector` protocol (put/get/batches/refcounts/leases/futures/streams)
over a consistent-hash ring of KV shards (ROADMAP item 1 — the paper's
single mediated channel, scaled out):

* **Routing** — keys hash onto a ring of ~``vnodes`` virtual nodes per
  shard (:class:`HashRing`); an object's *owners* are the first
  ``replication`` distinct shards clockwise from its hash.  Keys are
  location-free ``("fkv", object_id)`` tuples: any process rebuilding the
  connector from ``config()`` maps a key to its owners via the ring, so
  proxies resolve anywhere without embedding a server address.

* **Replicated puts** — by default (``chain=True``) a put uploads ONE
  copy to the first usable owner, which **chain-forwards** it to the
  remaining owners over shard-to-shard connections with per-hop acks
  (``put2``/``mput2`` + ``"chain"``) — client egress is ~1/R of the
  legacy client-fanout path.  A successor the head cannot reach is
  queued for **repair** (:meth:`ShardedConnector.repair_replicas`
  re-puts the blob when the shard answers again), and a put whose ring
  primary is suspect lands on the next usable successor with a
  **hinted-handoff** record — the landing shard replays bytes +
  refcount + lease to the owner on recovery
  (:meth:`ShardedConnector.replay_hints`, triggered automatically by
  the first successful exchange with the recovered shard).  With
  ``chain=False`` the legacy path applies: the client submits to every
  owner pipelined, first ack commits, replicas drain in the background
  (``quorum=True`` awaits them all).  Either way a put succeeds iff
  **at least one** owner acked — with ``replication=2`` the fabric
  tolerates any single shard death without losing a committed put.

* **Read failover** — a read tries owners in ring order; a dead or
  timed-out shard is marked *suspect* (:class:`ShardHealth`, the
  ``HeartbeatMonitor`` shape: half-open probes with monotonic backoff,
  ``alive()``/``dead()`` views) and the read falls over to the next
  replica.  Idempotent ops additionally retry through each
  ``KVClient``'s transparent-reconnect path, governed by
  :class:`~repro.distributed.fault_tolerance.RetryPolicy`.

* **Live rebalancing** — :meth:`add_shard` / :meth:`remove_shard`
  migrate only the ring-adjacent slot ranges that change hands, in three
  phases: (1) bulk-copy missing replicas shard→shard with ``mget2`` /
  ``mput2`` batch streaming, no lock held; (2) briefly block puts, copy
  the delta journal, swap the ring; (3) prune keys from shards that no
  longer own them.  Refcounts and leases migrate with their keys
  (``keyspace`` op → ``incref(n)`` + ``touch(remaining)``), so ownership
  semantics survive shard membership changes.

* **Streams** — a topic hashes to a home shard like any key (its ring
  primary; a ``<topic>.dlq`` dead-letter sibling co-homes with its
  parent); the pub/sub group ops (``stream_subscribe`` /
  ``stream_take`` / ``stream_ack`` …) run there.  On first contact the
  fabric installs the topic's **replica chain** (its other ring owners)
  on the home shard: appends forward payloads and group-state snapshots
  to the chain before acking, and every cursor mutation pushes a
  coalesced snapshot — so when the home shard dies mid-stream, the next
  ring owner already holds the events AND the group cursors, and the
  re-homed group **resumes from its replicated cursor**.  Stream
  delivery across failover is therefore **at-least-once**: committed
  (producer-acked) events are never skipped, but events delivered just
  before a crash may be redelivered — consumers needing exactly-once
  must dedup by ``seq`` (each event's seq is stable across failover).
  Events requeued more than ``max_deliveries`` times move to
  ``<topic>.dlq`` with failure metadata instead of spinning forever.

**Limitations** (documented, not bugs): a key is readable-while-absent
on a lagging replica (chain repair / hint replay in flight) — readers
fall through a miss to the other owners before declaring None; the
cursor push that follows a delivery is asynchronous, so a crash between
delivery and push redelivers (never skips) events; and repair/hint
queues are held in client memory — a fabric client that exits before
``repair_replicas()``/``replay_hints()`` drain leaves the ring one
replica short until the next rebalance.

Fault injection for all of the above lives in
:mod:`repro.distributed.chaos`; `benchmarks/fig15_fabric.py` measures
aggregate throughput vs shard count and kill-a-shard recovery time.
"""
from __future__ import annotations

import bisect
import logging
import threading
import time
import uuid
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FuturesTimeout
from concurrent.futures import wait as futures_wait
from hashlib import blake2b
from typing import Any, Sequence

from repro.core.connector import BaseConnector, Key, StreamItem
from repro.core.kv_tcp import KVClient, is_uds, stream_item_key
from repro.distributed.fault_tolerance import RetryPolicy
from repro.stream.broker import BrokerEvent

log = logging.getLogger(__name__)

_CONN_ERRORS = (ConnectionError, FuturesTimeout, OSError)


def _hash(s: str) -> int:
    return int.from_bytes(blake2b(s.encode(), digest_size=8).digest(), "big")


def _canon(addr) -> str:
    """Canonical shard id: ``host:port`` for TCP, the ``unix:/path``
    address verbatim for Unix-domain shards."""
    if isinstance(addr, (tuple, list)):
        host, port = addr
        return host if is_uds(host) else f"{host}:{int(port)}"
    return str(addr)


def _split(sid: str) -> tuple[str, int]:
    if is_uds(sid):
        return sid, 0
    host, _, port = sid.rpartition(":")
    return host, int(port)


class HashRing:
    """Immutable consistent-hash ring with virtual nodes.

    Membership changes produce a NEW ring (``plus``/``minus``) with a
    bumped ``version`` — readers snapshot one reference and never see a
    half-updated ring; only the slot ranges adjacent to the changed
    shard map differently between versions.
    """

    __slots__ = ("shards", "vnodes", "version", "_hashes", "_sids")

    def __init__(self, shards: Sequence, vnodes: int = 64,
                 version: int = 0) -> None:
        self.shards = tuple(dict.fromkeys(_canon(s) for s in shards))
        if not self.shards:
            raise ValueError("HashRing needs at least one shard")
        self.vnodes = int(vnodes)
        self.version = int(version)
        pts = sorted((_hash(f"{sid}#{v}"), sid)
                     for sid in self.shards for v in range(self.vnodes))
        self._hashes = [h for h, _ in pts]
        self._sids = [s for _, s in pts]

    def plus(self, sid: str) -> "HashRing":
        return HashRing(self.shards + (_canon(sid),), self.vnodes,
                        self.version + 1)

    def minus(self, sid: str) -> "HashRing":
        rest = tuple(s for s in self.shards if s != _canon(sid))
        return HashRing(rest, self.vnodes, self.version + 1)

    def owners(self, key: str, n: int = 1) -> list[str]:
        """First ``n`` distinct shards clockwise from ``key``'s hash —
        owners[0] is the primary, the rest are its replicas."""
        n = min(n, len(self.shards))
        npts = len(self._hashes)
        i = bisect.bisect(self._hashes, _hash(key)) % npts
        out: list[str] = []
        for j in range(npts):
            sid = self._sids[(i + j) % npts]
            if sid not in out:
                out.append(sid)
                if len(out) == n:
                    break
        return out

    def primary(self, key: str) -> str:
        return self.owners(key, 1)[0]


class ShardHealth:
    """Suspect-tracking in the ``HeartbeatMonitor`` shape (``alive()`` /
    ``dead()``), plus a half-open probe circuit: a suspect shard is
    skipped by reads/writes until its monotonic backoff elapses, at which
    point ONE attempt is let through (``usable()`` returns True and
    pushes the next probe out); success (``mark_ok``) closes the circuit.
    Monotonic clock only — a wall-clock step can't mass-un-suspect."""

    def __init__(self, probe_base_s: float = 0.25,
                 probe_max_s: float = 4.0) -> None:
        self.probe_base_s = float(probe_base_s)
        self.probe_max_s = float(probe_max_s)
        self._lock = threading.Lock()
        self._state: dict[str, dict] = {}

    def mark_suspect(self, sid: str) -> None:
        now = time.monotonic()
        with self._lock:
            st = self._state.setdefault(
                sid, {"since": now, "backoff": self.probe_base_s})
            st["next_probe"] = now + st["backoff"]

    def mark_ok(self, sid: str) -> None:
        with self._lock:
            self._state.pop(sid, None)

    forget = mark_ok

    def usable(self, sid: str) -> bool:
        with self._lock:
            st = self._state.get(sid)
            if st is None:
                return True
            now = time.monotonic()
            if now >= st["next_probe"]:        # half-open: one probe
                st["backoff"] = min(st["backoff"] * 2, self.probe_max_s)
                st["next_probe"] = now + st["backoff"]
                return True
            return False

    def suspects(self) -> list[str]:
        with self._lock:
            return sorted(self._state)

    def alive(self, known: Sequence[str]) -> dict[str, dict]:
        with self._lock:
            return {sid: {} for sid in known if sid not in self._state}

    def dead(self, known: Sequence[str]) -> list[str]:
        alive = self.alive(known)
        return [s for s in known if s not in alive]


class ShardedConnector(BaseConnector):
    """Connector over a consistent-hash ring of KV shards (module doc).

    ``shards`` — addresses: ``"host:port"``, ``(host, port)``, or
    ``"unix:/path"``.  ``replication`` — owners per key (primary +
    R-1 ring successors).  ``quorum`` — synchronous replica acks on put.
    ``op_timeout`` — per-exchange client timeout (this bounds how long a
    black-holed shard can stall one failover hop).
    """

    def __init__(self, shards: Sequence, replication: int = 2,
                 quorum: bool = False, op_timeout: float = 10.0,
                 vnodes: int = 64,
                 retry_policy: RetryPolicy | None = None,
                 chain: bool = True) -> None:
        self.replication = max(1, int(replication))
        self.quorum = bool(quorum)
        self.op_timeout = float(op_timeout)
        self.vnodes = int(vnodes)
        self.chain = bool(chain)
        # total-deadline cap: a retry loop on the failover path gives up
        # and reroutes instead of backing off past two op timeouts
        self.retry_policy = retry_policy or RetryPolicy(
            max_attempts=2, base_delay_s=0.05, max_delay_s=0.5,
            deadline_s=2.0 * self.op_timeout)
        self._ring = HashRing(shards, vnodes=self.vnodes)
        self._ring_lock = threading.Lock()     # ring swap + put journal
        self._admin_lock = threading.Lock()    # one rebalance at a time
        self._journal: set[str] | None = None  # puts issued mid-rebalance
        self._clients: dict[str, KVClient] = {}
        self._clients_lock = threading.Lock()
        self._health = ShardHealth()
        self._repl_lock = threading.Lock()
        self._repl_futs: set[Future] = set()
        self.n_failovers = 0       # reads served off the first-choice owner
        self.n_repl_errors = 0     # replica writes that failed
        self.n_repaired = 0        # repaired replica copies (re-puts)
        self.n_hints_replayed = 0  # hinted keys replayed to recovered owners
        # failed replica writes queue here until the missed owner answers
        # again: (sid, oid) -> blob
        self._repair_lock = threading.Lock()
        self._repair_q: dict[tuple[str, str], Any] = {}
        # hinted handoff bookkeeping: suspect owner -> landing shards that
        # hold hint records for it (replayed on the owner's recovery)
        self._hint_lock = threading.Lock()
        self._hints_out: dict[str, set[str]] = {}
        # stream plane: client-side subscription registry so a topic's
        # groups can be re-established on its next owner after failover
        self._streams_lock = threading.Lock()
        self._stream_subs: dict[tuple[str, str], dict] = {}
        self._stream_limits: dict[str, int] = {}
        self._stream_maxdel: dict[str, int] = {}
        self._stream_home: dict[str, str] = {}

    # -- shard plumbing ------------------------------------------------------
    def _client(self, sid: str) -> KVClient:
        with self._clients_lock:
            c = self._clients.get(sid)
            if c is None:
                host, port = _split(sid)
                c = self._clients[sid] = KVClient(
                    host, port, timeout=self.op_timeout,
                    retry_policy=self.retry_policy)
            return c

    def _suspect(self, sid: str) -> None:
        if sid not in self._health.suspects():
            log.warning("fabric: shard %s suspect", sid)
        self._health.mark_suspect(sid)

    def _owners(self, oid: str, ring: HashRing | None = None) -> list[str]:
        return (ring or self._ring).owners(oid, self.replication)

    def _ordered(self, owners: list[str]) -> list[str]:
        """Usable owners first (ring order preserved), suspects last —
        a read only pays a suspect's connect attempt as a final resort."""
        up = [s for s in owners if self._health.usable(s)]
        return up + [s for s in owners if s not in up]

    def _journal_add(self, oids) -> HashRing:
        """Record in-flight put ids while a rebalance is copying (so its
        delta phase re-replicates them under the new ring); returns the
        ring snapshot the put should route by."""
        with self._ring_lock:
            if self._journal is not None:
                self._journal.update(oids)
            return self._ring

    def _track_replica(self, sid: str, fut: Future) -> None:
        with self._repl_lock:
            self._repl_futs.add(fut)

        def _done(f: Future, sid=sid) -> None:
            with self._repl_lock:
                self._repl_futs.discard(f)
            if f.cancelled() or f.exception() is not None:
                self.n_repl_errors += 1
                self._suspect(sid)

        fut.add_done_callback(_done)

    def flush_replicas(self, timeout: float = 30.0) -> None:
        """Barrier the async replication tail (quorum mode has none)."""
        with self._repl_lock:
            futs = list(self._repl_futs)
        if futs:
            futures_wait(futs, timeout=timeout)

    # -- recovery plumbing: replica repair + hinted handoff ------------------
    def _mark_ok(self, sid: str) -> None:
        """``mark_ok`` plus the recovery hook: the first successful
        exchange with a shard we owe hinted keys or queued repairs
        triggers their replay — no background thread, recovery rides on
        ordinary traffic."""
        self._health.mark_ok(sid)
        with self._hint_lock:
            owed_hints = sid in self._hints_out
        if owed_hints:
            self.replay_hints(owner=sid)
        with self._repair_lock:
            owed_repair = any(s == sid for s, _ in self._repair_q)
        if owed_repair:
            self.repair_replicas()

    def _note_hint(self, owner: str, landing: str) -> None:
        with self._hint_lock:
            self._hints_out.setdefault(owner, set()).add(landing)

    def _enqueue_repair(self, sid: str, oid: str, blob) -> None:
        """Remember a replica write that failed so it can be re-put when
        ``sid`` answers again (the blob is pinned client-side until then
        — module-doc limitation)."""
        with self._repair_lock:
            self._repair_q[(sid, oid)] = blob

    def repair_replicas(self) -> int:
        """Re-put queued failed replica writes to shards that answer
        again.  Entries whose shard no longer owns the key (the ring
        moved) are dropped — the rebalance re-replicated them.  Returns
        how many copies were repaired; also runs automatically from
        :meth:`_mark_ok` when a shard with queued repairs recovers."""
        with self._repair_lock:
            entries = list(self._repair_q.items())
        repaired = 0
        for (sid, oid), blob in entries:
            if sid not in self._owners(oid):
                with self._repair_lock:
                    self._repair_q.pop((sid, oid), None)
                continue
            if not self._health.usable(sid):
                continue
            try:
                self._client(sid).put(oid, blob)
                self._health.mark_ok(sid)   # direct: no recursive hook
            except _CONN_ERRORS:
                self._suspect(sid)
                continue
            with self._repair_lock:
                self._repair_q.pop((sid, oid), None)
            repaired += 1
        self.n_repaired += repaired
        return repaired

    def replay_hints(self, owner: str | None = None) -> int:
        """Ask every landing shard holding hint records for ``owner``
        (or for any owner when None) to replay them — bytes + refcount +
        remaining lease land on the recovered shard.  Returns the number
        of keys replayed; runs automatically from :meth:`_mark_ok`."""
        with self._hint_lock:
            if owner is not None:
                pending = {owner: set(self._hints_out.get(owner, ()))}
            else:
                pending = {o: set(ls) for o, ls in self._hints_out.items()}
        replayed = 0
        for own, landings in pending.items():
            if not landings or not self._health.usable(own):
                continue
            for sid in sorted(landings):
                try:
                    replayed += self._client(sid).hint_replay(own)
                    self._health.mark_ok(sid)   # direct: no recursion
                except _CONN_ERRORS:
                    self._suspect(sid)
                    continue
                with self._hint_lock:
                    left = self._hints_out.get(own)
                    if left is not None:
                        left.discard(sid)
                        if not left:
                            self._hints_out.pop(own, None)
        self.n_hints_replayed += replayed
        return replayed

    # -- chain puts: one upload, server-side forwarding ----------------------
    def _chain_route(self, owners: list[str]
                     ) -> tuple[str | None, tuple[str, ...], str | None]:
        """Pick the chain head (first usable owner), its forward list,
        and the hinted-handoff target (the ring primary when it is
        suspect — the head stores a hint instead of forwarding to it)."""
        head = next((s for s in owners if self._health.usable(s)), None)
        if head is None:
            return None, (), None
        hint = owners[0] if head != owners[0] else None
        rest = tuple(s for s in owners if s not in (head, hint))
        return head, rest, hint

    def _put_chain(self, oid: str, blob, owners: list[str]) -> bool:
        """One chain-replicated put.  Returns False when no head is
        usable or the head itself fails (the caller falls back to the
        legacy client-fanout path); successor failures queue repairs
        rather than failing the put."""
        head, rest, hint = self._chain_route(owners)
        if head is None:
            return False
        try:
            resp = self._client(head).put_chain(oid, blob, chain=rest,
                                                hint_for=hint)
        except _CONN_ERRORS:
            self._suspect(head)
            return False
        self._mark_ok(head)
        if hint:
            self._note_hint(hint, head)
        for addr in resp.get("chain_errors") or ():
            sid = _canon(addr)
            self.n_repl_errors += 1
            self._suspect(sid)
            self._enqueue_repair(sid, oid, blob)
        return True

    def _chain_plan(self, oids: list[str], ring: HashRing
                    ) -> tuple[dict, list[int]]:
        """Group batch keys by (head, forwards, hint) — one ``mput2`` +
        chain per distinct route.  Keys with no usable head land in the
        returned ``slow`` list for the legacy per-key path."""
        groups: dict[tuple, list[int]] = {}
        slow: list[int] = []
        for i, oid in enumerate(oids):
            owners = self._owners(oid, ring)
            head, rest, hint = self._chain_route(owners)
            if head is None:
                slow.append(i)
                continue
            groups.setdefault((head, rest, hint), []).append(i)
        return groups, slow

    def _chain_submit(self, groups: dict, oids, blobs,
                      slow: list[int]) -> list:
        subs = []
        for (head, rest, hint), idxs in groups.items():
            try:
                subs.append(((head, rest, hint), idxs,
                             self._client(head).mput_chain_async(
                                 [oids[i] for i in idxs],
                                 [blobs[i] for i in idxs],
                                 chain=rest, hint_for=hint)))
            except _CONN_ERRORS:
                self._suspect(head)
                slow.extend(idxs)
        return subs

    def _chain_collect(self, subs: list, oids, blobs,
                       slow: list[int]) -> None:
        """Await each chain batch: a successful head commits its whole
        group (unreachable successors queue repairs); a failed head
        drops its keys to ``slow`` for the legacy path."""
        for (head, rest, hint), idxs, f in subs:
            resp: dict = {}
            try:
                resp = f.result(self.op_timeout) or {}
            except _CONN_ERRORS:
                pass
            if not resp.get("ok"):
                self._suspect(head)
                slow.extend(idxs)
                continue
            self._mark_ok(head)
            if hint:
                self._note_hint(hint, head)
            for addr in resp.get("chain_errors") or ():
                sid = _canon(addr)
                self.n_repl_errors += 1
                self._suspect(sid)
                for i in idxs:
                    self._enqueue_repair(sid, oids[i], blobs[i])

    # -- puts: replicate to all owners, pipelined ----------------------------
    def put(self, blob) -> Key:
        oid = uuid.uuid4().hex
        self._put_object(oid, blob)
        return ("fkv", oid)

    def _put_object(self, oid: str, blob) -> None:
        ring = self._journal_add((oid,))
        owners = self._owners(oid, ring)
        if (self.chain and len(owners) > 1
                and self._put_chain(oid, blob, owners)):
            return
        targets = [s for s in owners if self._health.usable(s)] or owners
        futs: list[tuple[str, Future]] = []
        for sid in targets:            # all submits before any wait
            try:
                futs.append((sid, self._client(sid).put_async(oid, blob)))
            except _CONN_ERRORS:
                self._suspect(sid)
        if not futs:
            raise ConnectionError(f"fabric: no shard accepted put {oid} "
                                  f"(owners {owners})")
        if self.quorum:
            acks = 0
            for sid, f in futs:
                try:
                    f.result(self.op_timeout)
                    self._mark_ok(sid)
                    acks += 1
                except _CONN_ERRORS:
                    self._suspect(sid)
            if not acks:
                raise ConnectionError(f"fabric: put {oid} got no ack")
        else:
            # async chain: first ack commits; the rest drain in background
            acked = False
            for i, (sid, f) in enumerate(futs):
                if acked:
                    self._track_replica(sid, f)
                    continue
                try:
                    f.result(self.op_timeout)
                    self._mark_ok(sid)
                    acked = True
                except _CONN_ERRORS:
                    self._suspect(sid)
            if not acked:
                raise ConnectionError(f"fabric: put {oid} got no ack")

    def put_batch(self, blobs: Sequence) -> list[Key]:
        if not blobs:
            return []
        oids = [uuid.uuid4().hex for _ in blobs]
        ring = self._journal_add(oids)
        if self.chain and self.replication > 1 and len(ring.shards) > 1:
            groups, slow = self._chain_plan(oids, ring)
            subs = self._chain_submit(groups, oids, blobs, slow)
            self._chain_collect(subs, oids, blobs, slow)
            for i in slow:                 # no usable head: legacy fanout
                self._put_object(oids[i], blobs[i])
            return [("fkv", oid) for oid in oids]
        # legacy: one mput2 per shard covering every key it owns (primary
        # or replica); all batches are in flight before any ack is awaited
        shard_items: dict[str, list[int]] = {}
        targets_per_key: list[list[str]] = []
        for i, oid in enumerate(oids):
            owners = self._owners(oid, ring)
            targets = ([s for s in owners if self._health.usable(s)]
                       or owners)
            targets_per_key.append(targets)
            for sid in targets:
                shard_items.setdefault(sid, []).append(i)
        futs: dict[str, Future] = {}
        for sid, idxs in shard_items.items():
            try:
                futs[sid] = self._client(sid).mput_async(
                    [oids[i] for i in idxs], [blobs[i] for i in idxs])
            except _CONN_ERRORS:
                self._suspect(sid)
        acked: set[str] = set()
        for sid, f in futs.items():
            try:
                f.result(self.op_timeout)
                self._mark_ok(sid)
                acked.add(sid)
            except _CONN_ERRORS:
                self._suspect(sid)
        for i, targets in enumerate(targets_per_key):
            if not any(s in acked for s in targets):
                raise ConnectionError(
                    f"fabric: batch put lost key {oids[i]} "
                    f"(no owner ack among {targets})")
        return [("fkv", oid) for oid in oids]

    # -- reads: failover through the replica chain ---------------------------
    def get(self, key: Key):
        return self._get_object(key[1])

    def _get_object(self, oid: str):
        owners = self._owners(oid)
        failed_over = False
        for sid in self._ordered(owners):
            try:
                data = self._client(sid).get(oid)
            except _CONN_ERRORS:
                self._suspect(sid)
                failed_over = True
                continue
            self._mark_ok(sid)
            if data is not None:
                if failed_over or sid != owners[0]:
                    self.n_failovers += 1
                return data
            # miss on this owner (async replication lag or true absence):
            # fall through to the other replicas before declaring None
            failed_over = True
        return None

    def get_batch(self, keys: Sequence[Key]) -> list:
        if not keys:
            return []
        oids = [k[1] for k in keys]
        out: list = [None] * len(keys)
        groups: dict[str, list[int]] = {}
        for i, oid in enumerate(oids):
            owners = self._owners(oid)
            pref = next((s for s in owners if self._health.usable(s)),
                        owners[0])
            if pref != owners[0]:
                self.n_failovers += 1      # served off the ring primary
            groups.setdefault(pref, []).append(i)
        futs = []
        for sid, idxs in groups.items():
            try:
                futs.append(
                    (sid, idxs,
                     self._client(sid).mget_async([oids[i] for i in idxs])))
            except _CONN_ERRORS:
                self._suspect(sid)
                futs.append((sid, idxs, None))
        slow: list[int] = []       # per-key failover path
        for sid, idxs, f in futs:
            if f is None:
                slow.extend(idxs)
                continue
            try:
                blobs = f.result(self.op_timeout)
            except _CONN_ERRORS:
                self._suspect(sid)
                slow.extend(idxs)
                continue
            self._mark_ok(sid)
            for i, b in zip(idxs, blobs):
                if b is None:
                    slow.append(i)
                else:
                    out[i] = b
        for i in slow:
            out[i] = self._get_object(oids[i])
        return out

    def exists(self, key: Key) -> bool:
        oid = key[1]
        for sid in self._ordered(self._owners(oid)):
            try:
                if self._client(sid).exists(oid):
                    self._mark_ok(sid)
                    return True
                self._mark_ok(sid)
            except _CONN_ERRORS:
                self._suspect(sid)
        return False

    def exists_batch(self, keys: Sequence[Key]) -> list[bool]:
        return [self.exists(k) for k in keys]

    # -- evict + lifecycle: fan out to every owner ---------------------------
    def _fanout(self, oid: str, op) -> list:
        """Apply ``op(client, oid)`` on every owner; returns the successful
        results (≥1 required — a mutation must land somewhere)."""
        results, errors = [], []
        for sid in self._owners(oid):
            try:
                results.append(op(self._client(sid), oid))
                self._mark_ok(sid)
            except _CONN_ERRORS as e:
                self._suspect(sid)
                errors.append((sid, e))
        if not results and errors:
            raise ConnectionError(
                f"fabric: op failed on every owner of {oid}: {errors[-1]}")
        return results

    def evict(self, key: Key) -> None:
        self._fanout(key[1], lambda c, o: c.evict(o))

    def evict_batch(self, keys: Sequence[Key]) -> None:
        groups: dict[str, list[str]] = {}
        for k in keys:
            for sid in self._owners(k[1]):
                groups.setdefault(sid, []).append(k[1])
        for sid, oids in groups.items():
            try:
                self._client(sid).mevict(oids)
            except _CONN_ERRORS:
                self._suspect(sid)

    def incref(self, key: Key, n: int = 1) -> int:
        return max(self._fanout(key[1], lambda c, o: c.incref(o, n)))

    def decref(self, key: Key, n: int = 1) -> int:
        # each owner decrefs (and hard-evicts at zero) independently —
        # counts replicate with puts/rebalances, so owners agree
        return max(self._fanout(key[1], lambda c, o: c.decref(o, n)))

    def refcount(self, key: Key) -> int:
        oid = key[1]
        for sid in self._ordered(self._owners(oid)):
            try:
                n = self._client(sid).refcount(oid)
                self._mark_ok(sid)
                return n
            except _CONN_ERRORS:
                self._suspect(sid)
        raise ConnectionError(f"fabric: refcount({oid}) unreachable")

    def touch(self, key: Key, ttl: float | None) -> bool:
        return any(self._fanout(key[1], lambda c, o: c.touch(o, ttl)))

    def _lifecycle_batch(self, keys: Sequence[Key], method: str,
                         *args) -> list:
        """Group keys by owner, ONE batched exchange per shard; per-key
        result is the max across its owners."""
        oids = [k[1] for k in keys]
        groups: dict[str, list[int]] = {}
        for i, oid in enumerate(oids):
            for sid in self._owners(oid):
                groups.setdefault(sid, []).append(i)
        out: list = [0] * len(keys)
        ok_any = [False] * len(keys)
        for sid, idxs in groups.items():
            try:
                res = getattr(self._client(sid), method)(
                    [oids[i] for i in idxs], *args)
                self._mark_ok(sid)
            except _CONN_ERRORS:
                self._suspect(sid)
                continue
            for i, r in zip(idxs, res or [None] * len(idxs)):
                ok_any[i] = True
                if r is not None and r > out[i]:
                    out[i] = r
        if not all(ok_any):
            raise ConnectionError("fabric: lifecycle batch lost keys "
                                  "(no reachable owner)")
        return out

    def incref_batch(self, keys: Sequence[Key], n: int = 1) -> list[int]:
        return self._lifecycle_batch(keys, "mincref", n)

    def decref_batch(self, keys: Sequence[Key], n: int = 1) -> list[int]:
        return self._lifecycle_batch(keys, "mdecref", n)

    def touch_batch(self, keys: Sequence[Key], ttl: float | None) -> None:
        oids = [k[1] for k in keys]
        groups: dict[str, list[str]] = {}
        for oid in oids:
            for sid in self._owners(oid):
                groups.setdefault(sid, []).append(oid)
        for sid, shard_oids in groups.items():
            try:
                self._client(sid).mtouch(shard_oids, ttl)
            except _CONN_ERRORS:
                self._suspect(sid)

    # -- futures: reserved keys + parked wait with failover ------------------
    def reserve(self) -> Key:
        return ("fkv", uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._put_object(key[1], blob)   # the put wakes parked waiters

    def wait(self, key: Key, timeout: float = 60.0):
        """Parks inside the key's primary shard; a shard death mid-wait
        fails over to the next replica with the remaining timeout."""
        oid = key[1]
        deadline = time.monotonic() + float(timeout)
        last: BaseException | None = None
        for sid in self._ordered(self._owners(oid)):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                data = self._client(sid).wait(oid, remaining)
                self._mark_ok(sid)
                return data
            except TimeoutError as e:
                last = e
                break                    # a real timeout: no producer
            except _CONN_ERRORS as e:
                self._suspect(sid)
                self.n_failovers += 1
                last = e
        raise last if isinstance(last, TimeoutError) else TimeoutError(
            f"wait({oid}): no reachable owner ({last})")

    # -- streams: one home shard per topic, failover with re-subscribe -------
    def _topic_owners(self, topic: str) -> list[str]:
        # a dead-letter topic co-homes with its parent so poison events
        # never cross shards and rebalance moves them together
        base = topic[:-4] if topic.endswith(".dlq") else topic
        return self._owners(f"@t:{base}")

    def _ensure_stream_home(self, topic: str, sid: str,
                            client: KVClient) -> None:
        """First contact of ``topic`` on shard ``sid`` (initial bind or a
        post-failover re-home): install the topic's replica chain (its
        other ring owners — appends and cursor mutations replicate
        there), re-install its limits, and re-subscribe its groups.
        ``stream_sub`` is idempotent, so a group restored from a
        replicated snapshot keeps its cursor — the at-least-once
        resume."""
        with self._streams_lock:
            if self._stream_home.get(topic) == sid:
                return
            limit = self._stream_limits.get(topic)
            maxdel = self._stream_maxdel.get(topic)
            subs = [(g, spec) for (t, g), spec in self._stream_subs.items()
                    if t == topic]
        if self.chain and self.replication > 1:
            peers = [s for s in self._topic_owners(topic) if s != sid]
            client.stream_chain(topic, peers[:self.replication - 1])
        if limit or maxdel:
            client.stream_limit(topic, limit, max_deliveries=maxdel)
        for group, spec in subs:
            client.stream_sub(topic, group, "new", spec.get("filter"))
        with self._streams_lock:
            self._stream_home[topic] = sid

    def _stream_call(self, topic: str, fn):
        """Run ``fn(client)`` on the topic's home shard, failing over
        along its ring owners.  A parked-op TimeoutError is a real
        outcome (no producer/event) and propagates; only channel errors
        move the topic."""
        last: BaseException | None = None
        for sid in self._ordered(self._topic_owners(topic)):
            client = self._client(sid)
            try:
                self._ensure_stream_home(topic, sid, client)
                out = fn(client)
                self._mark_ok(sid)
                return out
            except TimeoutError:
                raise
            except _CONN_ERRORS as e:
                self._suspect(sid)
                with self._streams_lock:
                    self._stream_home.pop(topic, None)
                self.n_failovers += 1
                last = e
        raise ConnectionError(
            f"fabric: stream op on topic {topic!r} failed on every "
            f"owner ({last})")

    def stream_append(self, topic: str, blob, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        return self._stream_call(
            topic, lambda c: c.stream_append(topic, blob, ttl, meta=meta,
                                             timeout=timeout))

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    location: str | None = None) -> StreamItem:
        it = self._stream_call(
            topic, lambda c: c.stream_next(topic, seq, timeout))
        return StreamItem(seq, it["data"], it["available"], it["end"])

    def stream_fetch(self, topic: str, seqs,
                     location: str | None = None) -> list:
        return self._stream_call(topic,
                                 lambda c: c.stream_fetch(topic, seqs))

    def stream_close(self, topic: str, location: str | None = None) -> None:
        self._stream_call(topic, lambda c: c.stream_close(topic))

    # -- pub/sub consumer groups (subscriptions survive shard death) ---------
    def stream_subscribe(self, topic: str, group: str, start: str = "new",
                         filter: dict | None = None,  # noqa: A002
                         location: str | None = None) -> dict:
        out = self._stream_call(
            topic, lambda c: c.stream_sub(topic, group, start, filter))
        with self._streams_lock:
            self._stream_subs[(topic, group)] = {"filter": filter}
        return out

    def stream_unsubscribe(self, topic: str, group: str,
                           location: str | None = None) -> None:
        with self._streams_lock:
            self._stream_subs.pop((topic, group), None)
        self._stream_call(topic, lambda c: c.stream_unsub(topic, group))

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True,
                    location: str | None = None) -> BrokerEvent:
        it = self._stream_call(
            topic, lambda c: c.stream_take(topic, group, timeout, payload))
        if it["end"]:
            return BrokerEvent(-1, None, {}, end=True)
        return BrokerEvent(int(it["seq"]), it["data"], it["meta"])

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True,
                          location: str | None = None) -> list[BrokerEvent]:
        items = self._stream_call(
            topic, lambda c: c.stream_take_batch(topic, group, n, payload))
        return [BrokerEvent(it["seq"], it["data"], it["meta"])
                for it in items]

    def stream_ack(self, topic: str, group: str, seqs,
                   location: str | None = None) -> int:
        return self._stream_call(
            topic, lambda c: c.stream_ack(topic, group, seqs))

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None,
                       location: str | None = None) -> int:
        return self._stream_call(
            topic,
            lambda c: c.stream_requeue(topic, group, seqs, reason=reason))

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None,
                     location: str | None = None) -> None:
        with self._streams_lock:
            if limit:
                self._stream_limits[topic] = int(limit)
            else:
                self._stream_limits.pop(topic, None)
            if max_deliveries is not None:
                if max_deliveries:
                    self._stream_maxdel[topic] = int(max_deliveries)
                else:
                    self._stream_maxdel.pop(topic, None)
        self._stream_call(
            topic,
            lambda c: c.stream_limit(topic, limit,
                                     max_deliveries=max_deliveries))

    def stream_stat(self, topic: str,
                    location: str | None = None) -> dict:
        return self._stream_call(topic, lambda c: c.stream_stat(topic))

    # -- rebalancing ---------------------------------------------------------
    def add_shard(self, addr) -> None:
        """Join ``addr`` to the ring, migrating the slot ranges it now
        owns (bulk → delta → prune; puts only pause for the delta)."""
        sid = _canon(addr)
        with self._admin_lock:
            if sid in self._ring.shards:
                return
            self._migrate(self._ring.plus(sid))
        log.info("fabric: shard %s joined (ring v%d)", sid,
                 self._ring.version)

    def remove_shard(self, addr, dead: bool = False) -> None:
        """Leave ``addr`` (graceful drain) or repair after its death
        (``dead=True``: re-replicate its keys from surviving replicas —
        keys it held exclusively are unrecoverable and logged)."""
        sid = _canon(addr)
        with self._admin_lock:
            if sid not in self._ring.shards:
                return
            if len(self._ring.shards) == 1:
                raise ValueError("cannot remove the last shard")
            if dead:
                self._suspect(sid)
            self._migrate(self._ring.minus(sid),
                          exclude={sid} if dead else set())
            with self._clients_lock:
                c = self._clients.pop(sid, None)
            if c is not None:
                c.close()
            self._health.forget(sid)
        log.info("fabric: shard %s left (dead=%s, ring v%d)", sid, dead,
                 self._ring.version)

    def _migrate(self, new_ring: HashRing, exclude: set[str] = frozenset()
                 ) -> None:
        old_ring = self._ring
        sources = [s for s in old_ring.shards if s not in exclude]
        # phase 1: bulk copy, no lock — writes keep landing (journaled)
        with self._ring_lock:
            self._journal = set()
        holders: dict[str, list[str]] = {}
        refs: dict[str, int] = {}
        leases: dict[str, float] = {}
        reachable = []
        for sid in sources:
            try:
                ks = self._client(sid).keyspace()
                self._mark_ok(sid)
            except _CONN_ERRORS:
                self._suspect(sid)
                continue
            reachable.append(sid)
            for k in ks.get("keys", ()):
                holders.setdefault(k, []).append(sid)
            for k, n in ks.get("refs", {}).items():
                refs[k] = max(refs.get(k, 0), int(n))
            for k, t in ks.get("leases", {}).items():
                leases[k] = max(leases.get(k, 0.0), float(t))
        self._copy_missing(new_ring, holders, refs, leases)
        # phase 2: drain the delta journal and swap — puts block briefly
        with self._ring_lock:
            delta, self._journal = self._journal or set(), None
            if delta:
                d_holders = {
                    oid: [s for s in old_ring.owners(oid, self.replication)
                          if s not in exclude]
                    for oid in delta}
                self._copy_missing(new_ring, d_holders, {}, {})
            self._ring = new_ring
        # stream state moves separately: `keyspace` excludes stream items,
        # so topics (events + cursors + DLQ siblings) travel by snapshot
        self._migrate_streams(old_ring, new_ring, exclude)
        # phase 3: prune slot ranges that moved away (only on shards that
        # remain members; a graceful leaver is pruned empty here too)
        for sid in reachable:
            owned = [k for k in holders
                     if sid in holders[k]
                     and sid not in new_ring.owners(k, self.replication)]
            if not owned:
                continue
            try:
                self._client(sid).mevict(owned)
            except _CONN_ERRORS:
                self._suspect(sid)

    def _migrate_streams(self, old_ring: HashRing, new_ring: HashRing,
                         exclude: set[str] = frozenset()) -> None:
        """Move every client-known topic (plus its ``.dlq`` sibling) whose
        owner set changed: snapshot broker state off a surviving old
        owner, copy the retained payload keys, restore on the new owners,
        drop from shards leaving the owner set.  Group cursors, pending
        sets, delivery counts, and DLQ contents all ride the snapshot."""
        with self._streams_lock:
            topics = (set(self._stream_home) | set(self._stream_limits)
                      | set(self._stream_maxdel)
                      | {t for t, _ in self._stream_subs})
        topics |= {f"{t}.dlq" for t in list(topics)
                   if not t.endswith(".dlq")}
        for topic in sorted(topics):
            base = topic[:-4] if topic.endswith(".dlq") else topic
            old_owners = [s for s in old_ring.owners(f"@t:{base}",
                                                     self.replication)
                          if s not in exclude]
            new_owners = new_ring.owners(f"@t:{base}", self.replication)
            if set(old_owners) == set(new_owners):
                continue
            snap, src = None, None
            for sid in old_owners:          # freshest copy lives up front
                try:
                    snap = self._client(sid).stream_snap(topic)
                    self._mark_ok(sid)
                    src = sid
                    break
                except _CONN_ERRORS:
                    self._suspect(sid)
            if src is None or not (snap.get("count") or snap.get("groups")):
                continue                    # nothing to move
            keys = [stream_item_key(topic, int(s))
                    for s in snap.get("owners") or ()]
            pairs: list[tuple[str, Any]] = []
            if keys:
                try:
                    blobs = self._client(src).mget(keys)
                    pairs = [(k, b) for k, b in zip(keys, blobs)
                             if b is not None]
                except _CONN_ERRORS:
                    self._suspect(src)
                    continue
            for dst in new_owners:
                try:
                    c = self._client(dst)
                    if pairs:
                        c.mput([k for k, _ in pairs],
                               [b for _, b in pairs])
                    c.stream_restore(topic, snap)
                    self._mark_ok(dst)
                except _CONN_ERRORS:
                    self._suspect(dst)
            for sid in old_owners:
                if sid in new_owners:
                    continue
                try:
                    self._client(sid).stream_drop(topic)
                except _CONN_ERRORS:
                    self._suspect(sid)
            with self._streams_lock:
                self._stream_home.pop(topic, None)

    def _copy_missing(self, new_ring: HashRing,
                      holders: dict[str, list[str]], refs: dict[str, int],
                      leases: dict[str, float]) -> None:
        """Copy each key to the new-ring owners that lack it, batched per
        (source, dest) pair over mget2/mput2 — rebalance rides the same
        pipelined fast path as ordinary batch traffic."""
        plan: dict[tuple[str, str], list[str]] = {}
        lost = 0
        for oid, srcs in holders.items():
            if not srcs:
                lost += 1
                continue
            have = set(srcs)
            for dst in new_ring.owners(oid, self.replication):
                if dst not in have:
                    plan.setdefault((srcs[0], dst), []).append(oid)
        if lost:
            log.error("fabric: %d keys unrecoverable (no surviving "
                      "replica)", lost)
        for (src, dst), oids in plan.items():
            try:
                blobs = self._client(src).mget(oids)
                pairs = [(o, b) for o, b in zip(oids, blobs)
                         if b is not None]
                if not pairs:
                    continue
                self._client(dst).mput([o for o, _ in pairs],
                                       [b for _, b in pairs])
                # lifecycle state rides along: counts via incref(n),
                # leases re-anchored with their remaining seconds
                dc = self._client(dst)
                futs = [dc.submit({"op": "incref", "key": o, "n": refs[o]})
                        for o, _ in pairs if refs.get(o, 0) > 0]
                futs += [dc.submit({"op": "touch", "key": o,
                                    "ttl": leases[o]})
                         for o, _ in pairs if leases.get(o, 0) > 0]
                for f in futs:
                    f.result(self.op_timeout)
            except _CONN_ERRORS as e:
                log.warning("fabric: migrate %s -> %s failed (%d keys): %s",
                            src, dst, len(oids), e)
                self._suspect(src)

    # -- introspection / config ----------------------------------------------
    @property
    def ring(self) -> HashRing:
        return self._ring

    @property
    def shards(self) -> tuple[str, ...]:
        return self._ring.shards

    def pipeline(self) -> "FabricPipeline":
        """Open a :class:`FabricPipeline` — Redis-style pipelined bulk
        transfers: ``put_batch``/``get_batch``/``evict_batch`` submit their
        per-shard exchanges immediately and return without waiting; one
        ``flush()`` (or clean ``with``-exit) barriers every ack.  Because
        each shard connection is FIFO, a get submitted after a put of the
        same key on the same pipeline observes it — so a full round trip
        runs with all shards busy end to end instead of in lock-stepped
        put/get/evict phases."""
        return FabricPipeline(self)

    def stats(self) -> dict[str, Any]:
        with self._clients_lock:
            clients = dict(self._clients)
        per_shard: dict[str, Any] = {}
        for sid in self._ring.shards:
            c = clients.get(sid)
            if c is None:
                per_shard[sid] = None
                continue
            try:
                per_shard[sid] = c.stats()
            except _CONN_ERRORS:
                per_shard[sid] = None
        with self._repair_lock:
            repair_pending = len(self._repair_q)
        with self._hint_lock:
            hints_pending = sum(len(v) for v in self._hints_out.values())
        return {
            "fabric": {
                "n_shards": len(self._ring.shards),
                "ring_version": self._ring.version,
                "replication": self.replication,
                "quorum": self.quorum,
                "chain": self.chain,
                "n_failovers": self.n_failovers,
                "n_repl_errors": self.n_repl_errors,
                "n_repaired": self.n_repaired,
                "n_repairs_pending": repair_pending,
                "n_hints_replayed": self.n_hints_replayed,
                "n_hint_shards_pending": hints_pending,
                "suspect": self._health.suspects(),
                "n_reconnects": sum(c.n_reconnects
                                    for c in clients.values()),
                "n_retries": sum(c.n_retries for c in clients.values()),
                "client_tx_bytes": sum(c.n_tx_bytes
                                       for c in clients.values()),
            },
            "shards": per_shard,
        }

    def config(self) -> dict[str, Any]:
        return {"shards": list(self._ring.shards),
                "replication": self.replication, "quorum": self.quorum,
                "op_timeout": self.op_timeout, "vnodes": self.vnodes,
                "chain": self.chain}

    def close(self) -> None:
        self.flush_replicas(timeout=5.0)
        with self._clients_lock:
            clients, self._clients = list(self._clients.values()), {}
        for c in clients:
            c.close()
        super().close()


class PipelineResult:
    """Handle for a pipelined ``get_batch``: ``result()`` is valid only
    after the owning pipeline's ``flush()``."""

    __slots__ = ("_value", "_ready")

    def __init__(self) -> None:
        self._value: list | None = None
        self._ready = False

    def result(self) -> list:
        if not self._ready:
            raise RuntimeError("pipeline not flushed — call flush() "
                               "(or exit the with-block) first")
        return self._value  # type: ignore[return-value]


class FabricPipeline:
    """Pipelined bulk transfers over a :class:`ShardedConnector`.

    Every batch op submits its per-shard exchanges (``mput2``/``mget2``/
    ``mevict``) and returns immediately; ``flush()`` waits for all acks at
    once.  Per-connection FIFO ordering makes this correct: a shard
    processes the pipeline's puts before its gets, so a get of a key put
    earlier on the SAME pipeline always observes the value — while the
    client never idles between phases and all shards stay busy.

    Failure semantics are a superset of the plain batch ops: put acks are
    all awaited at flush (≥1 owner ack required per key, like
    ``put_batch`` with quorum), and any pipelined get that misses or whose
    shard died is transparently re-fetched through the connector's normal
    failover read path.
    """

    def __init__(self, fab: "ShardedConnector") -> None:
        self.fab = fab
        self._put_waits: list[tuple[dict, list, list[str]]] = []
        self._get_waits: list[tuple[list, dict, PipelineResult]] = []
        self._misc_waits: list[tuple[str, Future]] = []
        self._flushed = False

    # -- submits --------------------------------------------------------------
    def put_batch(self, blobs: Sequence) -> list[Key]:
        # Deliberately the legacy client-fanout path even when the fabric
        # defaults to chain replication: pipeline correctness rests on
        # per-connection FIFO (a later get/evict on the same shard
        # connection observes the put), and a server-side forward hop
        # would land on the replica AFTER a directly-submitted evict.
        fab = self.fab
        oids = [uuid.uuid4().hex for _ in blobs]
        ring = fab._journal_add(oids)
        shard_items: dict[str, list[int]] = {}
        targets_per_key: list[list[str]] = []
        for i, oid in enumerate(oids):
            owners = fab._owners(oid, ring)
            targets = ([s for s in owners if fab._health.usable(s)]
                       or owners)
            targets_per_key.append(targets)
            for sid in targets:
                shard_items.setdefault(sid, []).append(i)
        futs: dict[str, Future] = {}
        for sid, idxs in shard_items.items():
            try:
                futs[sid] = fab._client(sid).mput_async(
                    [oids[i] for i in idxs], [blobs[i] for i in idxs])
            except _CONN_ERRORS:
                fab._suspect(sid)
        self._put_waits.append((futs, oids, targets_per_key))
        return [("fkv", oid) for oid in oids]

    def get_batch(self, keys: Sequence[Key]) -> PipelineResult:
        fab = self.fab
        oids = [k[1] for k in keys]
        groups: dict[str, list[int]] = {}
        for i, oid in enumerate(oids):
            owners = fab._owners(oid)
            pref = next((s for s in owners if fab._health.usable(s)),
                        owners[0])
            if pref != owners[0]:
                fab.n_failovers += 1
            groups.setdefault(pref, []).append(i)
        futs: dict[str, tuple[list[int], Future | None]] = {}
        for sid, idxs in groups.items():
            try:
                futs[sid] = (idxs,
                             fab._client(sid).mget_async(
                                 [oids[i] for i in idxs]))
            except _CONN_ERRORS:
                fab._suspect(sid)
                futs[sid] = (idxs, None)
        res = PipelineResult()
        self._get_waits.append((oids, futs, res))
        return res

    def evict_batch(self, keys: Sequence[Key]) -> None:
        fab = self.fab
        groups: dict[str, list[str]] = {}
        for k in keys:
            for sid in fab._owners(k[1]):
                groups.setdefault(sid, []).append(k[1])
        for sid, oids in groups.items():
            try:
                self._misc_waits.append(
                    (sid, fab._client(sid).submit(
                        {"op": "mevict", "keys": oids})))
            except _CONN_ERRORS:
                fab._suspect(sid)

    # -- barrier --------------------------------------------------------------
    def flush(self) -> None:
        if self._flushed:
            return
        self._flushed = True
        fab = self.fab
        # puts: wait every owner ack; ≥1 per key or the put is lost
        for futs, oids, targets_per_key in self._put_waits:
            acked: set[str] = set()
            for sid, f in futs.items():
                try:
                    f.result(fab.op_timeout)
                    fab._mark_ok(sid)
                    acked.add(sid)
                except _CONN_ERRORS:
                    fab._suspect(sid)
            for oid, targets in zip(oids, targets_per_key):
                if not any(s in acked for s in targets):
                    raise ConnectionError(
                        f"fabric: pipelined put lost key {oid} "
                        f"(no owner ack among {targets})")
        # gets: collect; misses / dead shards re-fetch via failover reads
        for oids, futs, res in self._get_waits:
            out: list = [None] * len(oids)
            slow: list[int] = []
            for sid, (idxs, f) in futs.items():
                if f is None:
                    slow.extend(idxs)
                    continue
                try:
                    blobs = f.result(fab.op_timeout)
                except _CONN_ERRORS:
                    fab._suspect(sid)
                    slow.extend(idxs)
                    continue
                fab._mark_ok(sid)
                for i, b in zip(idxs, blobs):
                    if b is None:
                        slow.append(i)
                    else:
                        out[i] = b
            for i in slow:
                out[i] = fab._get_object(oids[i])
            res._value, res._ready = out, True
        # evicts and friends: best-effort acks
        for sid, f in self._misc_waits:
            try:
                f.result(fab.op_timeout)
                fab._mark_ok(sid)
            except _CONN_ERRORS:
                fab._suspect(sid)
        self._put_waits.clear()
        self._get_waits.clear()
        self._misc_waits.clear()

    def __enter__(self) -> "FabricPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.flush()
