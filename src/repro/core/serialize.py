"""Pytree-aware binary serialization for the Store layer.

The paper's Store pickles generic Python objects.  In a JAX framework the
dominant payloads are pytrees of device/numpy arrays (batches, parameter
shards, gradients), so the serializer here:

* encodes pytree structure + scalars/strings via msgpack (tuples preserved),
* carries array buffers as raw bytes (no pickle round-trip),
* supports bfloat16 (via ml_dtypes view tricks; numpy has no native bf16),
* optionally compresses with zstd,
* falls back to pickle for arbitrary Python objects, preserving the paper's
  "any Python object" contract.

Format: 4-byte magic ``PSJ1`` | 1-byte flags (bit0: zstd) | msgpack body.
"""
from __future__ import annotations

import pickle
from typing import Any

import msgpack
import numpy as np
import zstandard

_MAGIC = b"PSJ1"
_FLAG_ZSTD = 0x01

_EXT_ARRAY = 1
_EXT_PICKLE = 2
_EXT_BFLOAT16 = 3
_EXT_TUPLE = 4
_EXT_SET = 5

_DEFAULT_LEVEL = 3


def _pack_array(a: np.ndarray) -> msgpack.ExtType:
    if not a.flags.c_contiguous:
        a = np.ascontiguousarray(a)
    header = msgpack.packb([a.dtype.str, list(a.shape)])
    return msgpack.ExtType(_EXT_ARRAY, header + a.tobytes())


def _default(obj: Any):
    # Proxies serialize as their factory, NEVER as the (possibly unresolved)
    # target — checked before array duck-typing, which would resolve them.
    from repro.core.proxy import is_proxy

    if is_proxy(obj):
        return msgpack.ExtType(_EXT_PICKLE, pickle.dumps(obj, protocol=5))
    if isinstance(obj, tuple):
        return msgpack.ExtType(
            _EXT_TUPLE, msgpack.packb(list(obj), default=_default, strict_types=True)
        )
    if isinstance(obj, (set, frozenset)):
        return msgpack.ExtType(
            _EXT_SET, msgpack.packb(sorted(obj), default=_default, strict_types=True)
        )
    if isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            return msgpack.ExtType(_EXT_PICKLE, pickle.dumps(obj, protocol=5))
        return _pack_any_array(obj)
    if isinstance(obj, np.generic):
        return _pack_any_array(np.asarray(obj))
    # jax.Array and other ndarray-likes (duck-typed; avoids importing jax in
    # host-only processes such as connector servers).
    if hasattr(obj, "__array__") and hasattr(obj, "dtype") and hasattr(obj, "shape"):
        a = np.asarray(obj)  # for bf16 jax arrays this yields ml_dtypes.bfloat16
        if a.dtype.hasobject:
            return msgpack.ExtType(_EXT_PICKLE, pickle.dumps(obj, protocol=5))
        return _pack_any_array(a)
    return msgpack.ExtType(_EXT_PICKLE, pickle.dumps(obj, protocol=5))


def _pack_any_array(a: np.ndarray) -> msgpack.ExtType:
    """Handles extension dtypes (bfloat16, float8_*) whose dtype.str is
    an opaque void code — shipped as uint-views tagged with the dtype name."""
    if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
        name = str(a.dtype)
        itemsize = a.dtype.itemsize
        view = np.ascontiguousarray(a).view({1: np.uint8, 2: np.uint16,
                                             4: np.uint32}[itemsize])
        header = msgpack.packb([name, list(a.shape)])
        return msgpack.ExtType(_EXT_BFLOAT16, header + view.tobytes())
    return _pack_array(a)


def _split_header(data: bytes):
    unpacker = msgpack.Unpacker()
    unpacker.feed(data)
    header = unpacker.unpack()
    return header, unpacker.tell()


def _ext_hook(code: int, data: bytes):
    if code == _EXT_ARRAY:
        (dtype_str, shape), offset = _split_header(data)
        arr = np.frombuffer(data, dtype=np.dtype(dtype_str), offset=offset)
        return arr.reshape(shape).copy()  # copy -> writable, owns its memory
    if code == _EXT_BFLOAT16:
        (name, shape), offset = _split_header(data)
        import ml_dtypes

        dtype = np.dtype(getattr(ml_dtypes, name))
        uview = {1: np.uint8, 2: np.uint16, 4: np.uint32}[dtype.itemsize]
        raw = np.frombuffer(data, dtype=uview, offset=offset).reshape(shape)
        return raw.view(dtype).copy()
    if code == _EXT_TUPLE:
        return tuple(msgpack.unpackb(data, ext_hook=_ext_hook, raw=False,
                                     strict_map_key=False))
    if code == _EXT_SET:
        return set(msgpack.unpackb(data, ext_hook=_ext_hook, raw=False,
                                   strict_map_key=False))
    if code == _EXT_PICKLE:
        return pickle.loads(data)
    raise ValueError(f"unknown ext type {code}")


def serialize(obj: Any, *, compress: bool | None = None,
              level: int = _DEFAULT_LEVEL) -> bytes:
    """Serialize ``obj`` to bytes.

    ``compress=None`` (default) compresses only when the body exceeds 16 KiB —
    small control messages are latency-sensitive, bulk tensors are
    bandwidth-sensitive (paper §4: channel choice depends on object size).
    """
    body = msgpack.packb(obj, default=_default, use_bin_type=True,
                         strict_types=True)
    if compress is None:
        compress = len(body) > 16 * 1024
    flags = 0
    if compress:
        body = zstandard.ZstdCompressor(level=level).compress(body)
        flags |= _FLAG_ZSTD
    return _MAGIC + bytes([flags]) + body


def deserialize(data: bytes) -> Any:
    if bytes(data[:4]) != _MAGIC:
        raise ValueError("not a repro-serialized payload (bad magic)")
    flags = data[4]
    body = data[5:]
    if flags & _FLAG_ZSTD:
        body = zstandard.ZstdDecompressor().decompress(body)
    return msgpack.unpackb(body, ext_hook=_ext_hook, raw=False,
                           strict_map_key=False)
