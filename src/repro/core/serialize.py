"""Pytree-aware binary serialization for the Store layer.

The paper's Store pickles generic Python objects.  In a JAX framework the
dominant payloads are pytrees of device/numpy arrays (batches, parameter
shards, gradients), so the serializer here:

* encodes pytree structure + scalars/strings via msgpack (tuples preserved),
* ships large array payloads *out of band* as zero-copy memoryviews,
* supports bfloat16 / float8 extension dtypes (numpy has no native bf16),
* optionally compresses with zstd — decided per buffer, not per frame,
* falls back to pickle for arbitrary Python objects, preserving the paper's
  "any Python object" contract.

``zstandard`` is an *optional* dependency: without it frames are written
uncompressed, and only reading a zstd-compressed frame raises.

PSJ2 frame layout (``serialize`` returns a :class:`Frame` of segments; the
wire image is their concatenation)::

    offset 0   4     5          9           17
           | "PSJ2" | flags u8 | nbuf u32 | body_len u64 |
           | table: nbuf x (offset u64, stored u64, raw u64, bflags u64) |
           | msgpack body (zstd-compressed iff flags bit0)               |
           | pad to 64 B | buffer 0 | pad | buffer 1 | ... | buffer n-1  |

* ``flags`` bit0: the msgpack body is zstd-compressed.
* the table describes the out-of-band buffers: ``offset`` is from frame
  start (64-byte aligned), ``stored`` is the on-wire byte count, ``raw``
  the uncompressed byte count, ``bflags`` bit0 marks a zstd buffer.
* the body is the pytree: structure, scalars and small arrays inline;
  each large array is an ext record ``(dtype, shape, buffer_index)``.

``deserialize`` accepts a contiguous received frame (``bytes`` /
``bytearray`` / ``memoryview``) or a :class:`Frame` and returns arrays that
are zero-copy views over the input for uncompressed buffers — a round trip
performs no payload copies for contiguous arrays.

Legacy format: 4-byte magic ``PSJ1`` | 1-byte flags (bit0: zstd) | msgpack
body with arrays inline.  PSJ1 frames still deserialize (magic-dispatched)
so persisted objects survive the upgrade; ``serialize_v1`` keeps producing
them for compatibility tests.
"""
from __future__ import annotations

import pickle
import struct
from typing import Any, Iterator, Sequence

import msgpack
import numpy as np

_MAGIC_V1 = b"PSJ1"
_MAGIC_V2 = b"PSJ2"
_FLAG_ZSTD = 0x01           # frame flags bit0 (PSJ1: whole body; PSJ2: body)
_BUF_ZSTD = 0x01            # per-buffer flags bit0

_EXT_ARRAY = 1
_EXT_PICKLE = 2
_EXT_BFLOAT16 = 3
_EXT_TUPLE = 4
_EXT_SET = 5
_EXT_NDBUF = 6              # out-of-band array: (dtype, shape, buffer_index)

_DEFAULT_LEVEL = 3
_ALIGN = 64                 # out-of-band buffers are 64-byte aligned
_OOB_MIN = 512              # arrays below this ride inline in the body
_BODY_ZSTD_MIN = 16 * 1024  # auto-compress bodies larger than this
_BUF_ZSTD_MIN = 16 * 1024   # never compress buffers smaller than this
_SAMPLE_BYTES = 64 * 1024   # compressibility probe size
_SAMPLE_RATIO = 0.9         # probe must beat this ratio to compress

_HEADER = struct.Struct(">4sBIQ")    # magic | flags | nbuf | body_len
_TABLE = struct.Struct(">QQQQ")      # offset | stored | raw | bflags

_UINT_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


# ---------------------------------------------------------------------------
# optional zstd
# ---------------------------------------------------------------------------
_UNSET = object()
_zstd: Any = _UNSET


def _get_zstd():
    """Lazy optional import.  Returns the module or None when unavailable."""
    global _zstd
    if _zstd is _UNSET:
        try:
            import zstandard
            _zstd = zstandard
        except ImportError:
            _zstd = None
    return _zstd


def _require_zstd():
    z = _get_zstd()
    if z is None:
        raise RuntimeError(
            "this frame is zstd-compressed but the optional dependency "
            "'zstandard' is not installed; run `pip install zstandard` to "
            "read it (new frames are written uncompressed without it)")
    return z


# ---------------------------------------------------------------------------
# the multi-segment frame
# ---------------------------------------------------------------------------
class Frame:
    """A serialized object as a gather list of memoryview segments.

    ``segments`` concatenated are the wire image; connectors may write them
    with scatter-gather I/O instead of joining.  Payload segments alias the
    source arrays' memory — no ``tobytes()`` copies are made.  ``nbytes`` is
    the total wire size (``len()`` is deliberately not defined: a Frame is a
    segment sequence, not a byte string).
    """

    __slots__ = ("segments", "nbytes", "_flags", "_table", "_body", "_buffers")

    def __init__(self, segments: list, flags: int, table: list, body,
                 buffers: list) -> None:
        self.segments = segments
        self.nbytes = sum(memoryview(s).nbytes for s in segments)
        self._flags = flags          # frame flags (body compression)
        self._table = table          # [(offset, stored, raw, bflags), ...]
        self._body = body            # stored (possibly compressed) body
        self._buffers = buffers      # stored out-of-band segments, in order

    def __iter__(self) -> Iterator:
        return iter(self.segments)

    def __bytes__(self) -> bytes:
        return b"".join(self.segments)

    def to_bytes(self) -> bytes:
        return bytes(self)

    def write_into(self, view: memoryview) -> int:
        """Scatter the wire image into a caller-provided buffer (e.g. an
        arena slot or a reserved socket buffer): one memcpy per segment,
        no intermediate join.  Returns the byte count written."""
        return copy_segments_into(self.segments, view)


def as_segments(blob) -> list:
    """Normalize ``bytes | Frame | Sequence[memoryview]`` to a segment list.
    Buffer-protocol objects (numpy arrays, arrays.array, ...) become ONE
    flat segment — never iterated element-wise, which would shred a 1 MB
    array into 250k scalar segments."""
    if isinstance(blob, Frame):
        return blob.segments
    if isinstance(blob, (bytes, bytearray, memoryview)):
        return [blob]
    try:
        return [memoryview(blob).cast("B")]
    except TypeError:
        return list(blob)


def frame_nbytes(blob) -> int:
    """Total wire size of ``bytes | Frame | Sequence[memoryview]``."""
    if isinstance(blob, Frame):
        return blob.nbytes
    if isinstance(blob, (bytes, bytearray, memoryview)):
        return memoryview(blob).nbytes
    try:
        return memoryview(blob).nbytes
    except TypeError:
        return sum(memoryview(s).nbytes for s in blob)


def join_frame(blob) -> bytes:
    """Contiguous wire image (the copy connectors without scatter-gather pay)."""
    if isinstance(blob, bytes):
        return blob
    return b"".join(as_segments(blob))


def materialize(obj: Any):
    """Recursively copy zero-copy array views (and memoryviews) so the
    result OWNS its memory.

    ``deserialize`` returns arrays aliasing the input buffer; when that
    buffer is *borrowed* shared memory (an arena slot), dropping the last
    reference to the key lets the owner recycle the chunk underneath the
    arrays.  Call this before the reference drop (the Store's ephemeral /
    owned resolve paths do) to detach the result from the channel.
    Arrays that already own their data pass through untouched.
    """
    if isinstance(obj, np.ndarray):
        if obj.base is None and obj.flags.owndata:
            return obj
        return obj.copy()
    if isinstance(obj, memoryview):
        return bytes(obj)
    if isinstance(obj, dict):
        return {k: materialize(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [materialize(v) for v in obj]
    if isinstance(obj, tuple):
        return tuple(materialize(v) for v in obj)
    if isinstance(obj, (set, frozenset)):
        return type(obj)(materialize(v) for v in obj)
    return obj


def copy_segments_into(blob, view: memoryview) -> int:
    """Scatter ``bytes | Frame | Sequence[memoryview]`` into ``view``:
    one memcpy per segment straight into the destination (an arena slot, a
    pre-registered I/O buffer), never an intermediate join.  Returns the
    byte count written."""
    pos = 0
    for s in as_segments(blob):
        mv = memoryview(s)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        view[pos:pos + mv.nbytes] = mv
        pos += mv.nbytes
    return pos


# ---------------------------------------------------------------------------
# shared helpers (inline array packing, both formats)
# ---------------------------------------------------------------------------
def _raw_view(a: np.ndarray) -> memoryview:
    """Flat byte view of a C-contiguous array, incl. extension dtypes that
    do not export the buffer protocol (bfloat16, float8_*)."""
    try:
        return a.data.cast("B")
    except (ValueError, BufferError, TypeError):
        return a.view(_UINT_VIEW[a.dtype.itemsize]).data.cast("B")


def _dtype_name(a: np.ndarray) -> str:
    return a.dtype.str if _is_std_dtype(a.dtype) else str(a.dtype)


def _is_std_dtype(dtype: np.dtype) -> bool:
    try:
        return np.dtype(dtype.str) == dtype
    except TypeError:
        return False


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _pack_array(a: np.ndarray) -> msgpack.ExtType:
    if not a.flags.c_contiguous:
        a = np.ascontiguousarray(a)
    header = msgpack.packb([a.dtype.str, list(a.shape)])
    return msgpack.ExtType(_EXT_ARRAY, header + a.tobytes())


def _pack_any_array_inline(a: np.ndarray) -> msgpack.ExtType:
    """Inline packing for small / PSJ1 arrays.  Extension dtypes (bfloat16,
    float8_*) have a dtype.str numpy cannot re-parse — shipped as uint views
    tagged with the dtype name."""
    if not _is_std_dtype(a.dtype):
        name = str(a.dtype)
        view = np.ascontiguousarray(a).view(_UINT_VIEW[a.dtype.itemsize])
        header = msgpack.packb([name, list(a.shape)])
        return msgpack.ExtType(_EXT_BFLOAT16, header + view.tobytes())
    return _pack_array(a)


def _split_header(data: bytes):
    unpacker = msgpack.Unpacker()
    unpacker.feed(data)
    header = unpacker.unpack()
    return header, unpacker.tell()


def _ext_hook(code: int, data: bytes):
    """Ext decoding shared by PSJ1 and the inline part of PSJ2 bodies."""
    if code == _EXT_ARRAY:
        (dtype_str, shape), offset = _split_header(data)
        arr = np.frombuffer(data, dtype=np.dtype(dtype_str), offset=offset)
        return arr.reshape(shape).copy()  # copy -> writable, owns its memory
    if code == _EXT_BFLOAT16:
        (name, shape), offset = _split_header(data)
        import ml_dtypes

        dtype = np.dtype(getattr(ml_dtypes, name))
        uview = _UINT_VIEW[dtype.itemsize]
        raw = np.frombuffer(data, dtype=uview, offset=offset).reshape(shape)
        return raw.view(dtype).copy()
    if code == _EXT_TUPLE:
        return tuple(msgpack.unpackb(data, ext_hook=_ext_hook, raw=False,
                                     strict_map_key=False))
    if code == _EXT_SET:
        return set(msgpack.unpackb(data, ext_hook=_ext_hook, raw=False,
                                   strict_map_key=False))
    if code == _EXT_PICKLE:
        return pickle.loads(data)
    raise ValueError(f"unknown ext type {code}")


# ---------------------------------------------------------------------------
# PSJ2 encoding
# ---------------------------------------------------------------------------
class _FrameEncoder:
    """msgpack default hook that siphons large arrays out of band."""

    def __init__(self) -> None:
        self.buffers: list[memoryview] = []  # raw (uncompressed) views

    def _oob(self, a: np.ndarray) -> msgpack.ExtType:
        if not a.flags.c_contiguous:
            a = np.ascontiguousarray(a)
        index = len(self.buffers)
        self.buffers.append(_raw_view(a))  # memoryview keeps `a` alive
        meta = msgpack.packb([_dtype_name(a), list(a.shape), index])
        return msgpack.ExtType(_EXT_NDBUF, meta)

    def _array(self, a: np.ndarray) -> msgpack.ExtType:
        if a.nbytes >= _OOB_MIN:
            return self._oob(a)
        return _pack_any_array_inline(a)

    def default(self, obj: Any):
        # Proxies serialize as their factory, NEVER as the (possibly
        # unresolved) target — checked before array duck-typing, which would
        # resolve them.
        from repro.core.proxy import is_proxy

        if is_proxy(obj):
            return msgpack.ExtType(_EXT_PICKLE, pickle.dumps(obj, protocol=5))
        if isinstance(obj, tuple):
            return msgpack.ExtType(
                _EXT_TUPLE,
                msgpack.packb(list(obj), default=self.default,
                              strict_types=True))
        if isinstance(obj, (set, frozenset)):
            return msgpack.ExtType(
                _EXT_SET,
                msgpack.packb(sorted(obj), default=self.default,
                              strict_types=True))
        if isinstance(obj, np.ndarray):
            if obj.dtype.hasobject:
                return msgpack.ExtType(_EXT_PICKLE,
                                       pickle.dumps(obj, protocol=5))
            return self._array(obj)
        if isinstance(obj, np.generic):
            return self._array(np.asarray(obj))
        # jax.Array and other ndarray-likes (duck-typed; avoids importing jax
        # in host-only processes such as connector servers).
        if hasattr(obj, "__array__") and hasattr(obj, "dtype") \
                and hasattr(obj, "shape"):
            a = np.asarray(obj)  # bf16 jax arrays yield ml_dtypes.bfloat16
            if a.dtype.hasobject:
                return msgpack.ExtType(_EXT_PICKLE,
                                       pickle.dumps(obj, protocol=5))
            return self._array(a)
        return msgpack.ExtType(_EXT_PICKLE, pickle.dumps(obj, protocol=5))


def _compressible(view: memoryview, z, level: int) -> bool:
    """Probe the head of the buffer: already-compressed / random tensors
    (the common case for trained weights and fp payloads) stay raw."""
    sample = view[:_SAMPLE_BYTES] if view.nbytes > _SAMPLE_BYTES else view
    probe = z.ZstdCompressor(level=level).compress(sample)
    return len(probe) < _SAMPLE_RATIO * sample.nbytes


def _pad(n: int) -> int:
    return -n % _ALIGN


def serialize(obj: Any, *, compress: bool | None = None,
              level: int = _DEFAULT_LEVEL) -> Frame:
    """Serialize ``obj`` to a PSJ2 :class:`Frame` (gather list of segments).

    ``compress=None`` (default) decides *per buffer*: only buffers over 16 KiB
    whose head actually compresses are zstd'd; the msgpack body is compressed
    over 16 KiB.  ``compress=True`` forces a compression attempt on every
    buffer (kept only when smaller), ``compress=False`` disables it.  Without
    the optional ``zstandard`` package frames are always uncompressed.
    """
    enc = _FrameEncoder()
    body = msgpack.packb(obj, default=enc.default, use_bin_type=True,
                         strict_types=True)
    z = None if compress is False else _get_zstd()
    flags = 0
    if z is not None and (compress or
                          (compress is None and len(body) > _BODY_ZSTD_MIN)):
        body = z.ZstdCompressor(level=level).compress(body)
        flags |= _FLAG_ZSTD

    stored: list[tuple[Any, int, int]] = []  # (segment, raw_len, bflags)
    for view in enc.buffers:
        raw_len = view.nbytes
        seg: Any = view
        bflags = 0
        if z is not None and raw_len and (
                compress is True or
                (raw_len >= _BUF_ZSTD_MIN and _compressible(view, z, level))):
            packed = z.ZstdCompressor(level=level).compress(view)
            if len(packed) < raw_len:
                seg, bflags = packed, _BUF_ZSTD
        stored.append((seg, raw_len, bflags))

    nbuf = len(stored)
    header_len = _HEADER.size + _TABLE.size * nbuf
    pos = header_len + len(body)
    table: list[tuple[int, int, int, int]] = []
    layout: list[tuple[int, Any]] = []       # (pad_before, segment)
    for seg, raw_len, bflags in stored:
        pad = _pad(pos)
        offset = pos + pad
        stored_len = memoryview(seg).nbytes
        table.append((offset, stored_len, raw_len, bflags))
        layout.append((pad, seg))
        pos = offset + stored_len

    head = bytearray(_HEADER.pack(_MAGIC_V2, flags, nbuf, len(body)))
    for entry in table:
        head += _TABLE.pack(*entry)
    segments: list[Any] = [memoryview(bytes(head)), memoryview(body)]
    for pad, seg in layout:
        if pad:
            segments.append(memoryview(b"\x00" * pad))
        segments.append(memoryview(seg) if not isinstance(seg, memoryview)
                        else seg)
    return Frame(segments, flags, table, body,
                 [memoryview(s) for s, _, _ in stored])


def serialize_v1(obj: Any, *, compress: bool | None = None,
                 level: int = _DEFAULT_LEVEL) -> bytes:
    """Legacy single-``bytes`` PSJ1 frame (arrays inline, whole-frame zstd).

    Kept for backward-compat tests and for peers that predate PSJ2; new code
    should use :func:`serialize`.
    """
    enc = _FrameEncoder()
    enc._array = _pack_any_array_inline  # type: ignore[assignment] # no OOB
    body = msgpack.packb(obj, default=enc.default, use_bin_type=True,
                         strict_types=True)
    if compress is None:
        compress = len(body) > _BODY_ZSTD_MIN
    flags = 0
    if compress:
        z = _get_zstd()
        if z is not None:
            body = z.ZstdCompressor(level=level).compress(body)
            flags |= _FLAG_ZSTD
    return _MAGIC_V1 + bytes([flags]) + body


# ---------------------------------------------------------------------------
# decoding
# ---------------------------------------------------------------------------
def _array_from_buffer(buf, name: str, shape) -> np.ndarray:
    dtype = _resolve_dtype(name)
    return np.frombuffer(buf, dtype=dtype).reshape(shape)


def _decode_v2(flags: int, table, body, buffers) -> Any:
    if flags & _FLAG_ZSTD:
        body = _require_zstd().ZstdDecompressor().decompress(bytes(body))
    resolved: list[Any] = []
    for (offset, stored_len, raw_len, bflags), seg in zip(table, buffers):
        if bflags & _BUF_ZSTD:
            raw = _require_zstd().ZstdDecompressor().decompress(
                bytes(seg), max_output_size=raw_len)
            resolved.append(raw)
        else:
            resolved.append(seg)

    def hook(code: int, data: bytes):
        if code == _EXT_NDBUF:
            name, shape, index = msgpack.unpackb(data, raw=False)
            return _array_from_buffer(resolved[index], name, shape)
        return _ext_hook(code, data)

    return msgpack.unpackb(body, ext_hook=hook, raw=False,
                           strict_map_key=False)


def _decode_v1(mv: memoryview) -> Any:
    flags = mv[4]
    body = mv[5:]
    if flags & _FLAG_ZSTD:
        body = _require_zstd().ZstdDecompressor().decompress(bytes(body))
    return msgpack.unpackb(body, ext_hook=_ext_hook, raw=False,
                           strict_map_key=False)


def deserialize(data) -> Any:
    """Decode a PSJ1/PSJ2 frame.

    Accepts the contiguous wire image (``bytes``/``bytearray``/``memoryview``
    — e.g. a connector ``get`` result) or a :class:`Frame`.  For PSJ2,
    uncompressed array payloads come back as zero-copy views over the input
    buffer (read-only iff the input is); callers that need writable arrays
    copy explicitly.
    """
    if isinstance(data, Frame):
        return _decode_v2(data._flags, data._table, data._body, data._buffers)
    if not isinstance(data, (bytes, bytearray, memoryview)):
        data = join_frame(data)  # generic segment sequences: one gather copy
    mv = memoryview(data).cast("B")
    magic = bytes(mv[:4])
    if magic == _MAGIC_V1:
        return _decode_v1(mv)
    if magic != _MAGIC_V2:
        if magic == b"\xde\xde\xde\xde":
            # the arena sanitizer's poison fill: this payload's backing
            # chunk was freed while a reference to it was still live
            from repro.analysis.sanitize import check_view

            check_view(mv, what="serialized payload")
        raise ValueError("not a repro-serialized payload (bad magic)")
    if mv.nbytes < _HEADER.size:
        raise ValueError(
            f"truncated PSJ2 frame: need {_HEADER.size} header bytes, "
            f"got {mv.nbytes}")
    _, flags, nbuf, body_len = _HEADER.unpack_from(mv, 0)
    if mv.nbytes < _HEADER.size + _TABLE.size * nbuf:
        raise ValueError(
            f"truncated PSJ2 frame: table for {nbuf} buffers exceeds "
            f"{mv.nbytes} bytes")
    table = [_TABLE.unpack_from(mv, _HEADER.size + _TABLE.size * i)
             for i in range(nbuf)]
    body_off = _HEADER.size + _TABLE.size * nbuf
    frame_end = max([body_off + body_len] +
                    [off + stored for off, stored, _, _ in table])
    if frame_end > mv.nbytes:
        raise ValueError(
            f"truncated PSJ2 frame: need {frame_end} bytes, got {mv.nbytes}")
    body = mv[body_off:body_off + body_len]
    buffers = [mv[off:off + stored] for off, stored, _, _ in table]
    return _decode_v2(flags, table, body, buffers)
