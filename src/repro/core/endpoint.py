"""PS-endpoint: peer-connected in-memory object store (paper §4.2.2).

A single-threaded asyncio application (as in the paper) running three duties:

1. an in-memory object store (optional disk spill via ``--persist-dir``),
2. a client API server — local processes (EndpointConnector) issue
   get/put/exists/evict with a target ``endpoint_id``; requests whose
   endpoint_id is not ours are forwarded over a peer channel,
3. peering — on first contact with a remote endpoint, an offer/answer
   exchange via the relay server introduces the peers (Fig 4), after which a
   direct "data channel" (TCP here; SCTP-over-DTLS in the paper) carries all
   object traffic.  Channels are kept open and re-established on loss.

``--throttle-bps``/``--throttle-rtt`` emulate the WAN regimes of Fig 9
(including the paper's observed ~80 Mbps aiortc ceiling, §5.3.2).
"""
from __future__ import annotations

import argparse
import asyncio
import os
import struct
import uuid as uuid_mod
from pathlib import Path

import msgpack

from repro.core.kv_tcp import (MAX_FRAME, STREAM_LIMIT, LifetimeTable,
                               StreamTable, WaiterTable,
                               stream_append_locally, stream_group_op,
                               stream_item_key)

# ops that may PARK (futures wait / stream next / group take): handled on
# tasks both on the client API (so pipelined requests overtake them) and on
# the peer channel (so a parked wait never stalls the peer's read loop)
_PARKING_OPS = ("wait", "s_next", "s_next2")

_LEN = struct.Struct(">I")


def _frame(msg: dict) -> bytes:
    body = msgpack.packb(msg, use_bin_type=True)
    return _LEN.pack(len(body)) + body


async def _read(reader: asyncio.StreamReader) -> dict | None:
    try:
        header = await reader.readexactly(4)
        (length,) = _LEN.unpack(header)
        body = await reader.readexactly(length)
    except (asyncio.IncompleteReadError, ConnectionResetError, OSError):
        return None
    return msgpack.unpackb(body, raw=False, strict_map_key=False)


class PeerChannel:
    """A multiplexed request/response channel to one remote endpoint."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter,
                 throttle_bps: float | None, throttle_rtt: float) -> None:
        self.reader, self.writer = reader, writer
        self.throttle_bps, self.throttle_rtt = throttle_bps, throttle_rtt
        self._rid = 0
        self._pending: dict[int, asyncio.Future] = {}
        self._send_lock = asyncio.Lock()
        self.alive = True

    async def send(self, msg: dict) -> None:
        data = _frame(msg)
        async with self._send_lock:
            # WAN emulation: latency + serialization over the capped link
            if self.throttle_rtt:
                await asyncio.sleep(self.throttle_rtt / 2)
            if self.throttle_bps:
                await asyncio.sleep(len(data) / self.throttle_bps)
            self.writer.write(data)
            await self.writer.drain()

    async def request(self, msg: dict, timeout: float = 120.0) -> dict:
        self._rid += 1
        rid = self._rid
        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        self._pending[rid] = fut
        msg = dict(msg, rid=rid, kind="req")
        await self.send(msg)
        try:
            return await asyncio.wait_for(fut, timeout)
        finally:
            self._pending.pop(rid, None)

    def dispatch_response(self, msg: dict) -> None:
        fut = self._pending.get(msg.get("rid"))
        if fut is not None and not fut.done():
            fut.set_result(msg)

    def close(self) -> None:
        self.alive = False
        try:
            self.writer.close()
        except Exception:
            pass


class Endpoint:
    def __init__(self, *, uuid: str | None, relay_host: str, relay_port: int,
                 persist_dir: str | None = None,
                 throttle_bps: float | None = None,
                 throttle_rtt: float = 0.0) -> None:
        self.uuid = uuid  # may be assigned by the relay at registration
        self.relay_host, self.relay_port = relay_host, relay_port
        self.persist = Path(persist_dir) if persist_dir else None
        self.throttle_bps, self.throttle_rtt = throttle_bps, throttle_rtt
        self._data: dict[str, bytes] = {}
        self.lifetime = LifetimeTable(self._evict_object)
        self.waiters = WaiterTable()
        self.streams = StreamTable()
        self._n_ops = 0
        self._peers: dict[str, PeerChannel] = {}
        self._peer_dials: dict[str, "asyncio.Future[PeerChannel]"] = {}
        self._relay_writer: asyncio.StreamWriter | None = None
        self._relay_replies: dict[str, asyncio.Queue] = {}
        self._rid = 0
        self._shutdown = asyncio.Event()
        self._peer_host = "127.0.0.1"
        self._peer_port = 0
        if self.persist:
            self.persist.mkdir(parents=True, exist_ok=True)
            for f in self.persist.glob("*.obj"):
                self._data[f.stem] = f.read_bytes()

    # ------------------------------------------------------------------
    # local store ops
    # ------------------------------------------------------------------
    def _evict_object(self, oid: str) -> None:
        self._data.pop(oid, None)
        self.lifetime.drop(oid)
        if self.persist:
            (self.persist / f"{oid}.obj").unlink(missing_ok=True)

    def _store_obj(self, oid: str, data: bytes) -> None:
        """Every object write funnels through here so parked ``wait``-ers
        (local clients AND peer-forwarded ones) are released on put."""
        self._data[oid] = data
        self.waiters.wake(oid)

    def _touch(self, oid: str, ttl) -> bool:
        self.lifetime.touch(oid, ttl)
        return oid in self._data

    def _local(self, req: dict) -> dict:
        self._n_ops += 1
        self.lifetime.maybe_sweep()
        op = req["op"]
        oid = req.get("object_id")
        if op == "put":
            self._store_obj(oid, req["data"])
            if self.persist:
                (self.persist / f"{oid}.obj").write_bytes(req["data"])
            return {"ok": True}
        if op == "s_append":
            # data first, count bump + consumer wake second (a consumer
            # woken early would miss on its prefetch mget).  Grouped
            # topics store one reference per matching group; endpoints do
            # not park on s_limit bounds (backpressure is a KV-broker /
            # LocalBroker feature — an endpoint append never blocks the
            # single-threaded peer loop)
            return stream_append_locally(
                self.streams, self.lifetime, self._store_obj,
                req["topic"], req["data"], req.get("ttl"), req.get("meta"))
        if op in ("s_sub", "s_unsub", "s_ack", "s_requeue", "s_limit"):
            return stream_group_op(self.streams, self.lifetime,
                                   self._data.__contains__, req)
        if op == "s_fetch":
            # non-blocking batch take for one group: blobs ride in-band
            # here ("data" list); the client API loop / peer forwarder
            # convert them to the mget2-style raws wire format
            topic, group = req["topic"], req["group"]
            seqs: list[int] = []
            while len(seqs) < int(req.get("n", 1)):
                seq = self.streams.take(topic, group)
                if seq is None:
                    break
                seqs.append(seq)
            metas = self.streams.meta.get(topic, {})
            st = self.streams.state(topic)
            resp = {"ok": True, "seqs": seqs,
                    "metas": [metas.get(s) or {} for s in seqs],
                    "available": st["count"], "closed": st["closed"]}
            if req.get("payload", True):
                resp["data"] = [self._data.get(stream_item_key(topic, s))
                                for s in seqs]
            return resp
        if op == "s_close":
            self.streams.close(req["topic"])
            return {"ok": True}
        if op == "s_stat":
            return {"ok": True, "data": self.streams.describe(req["topic"])}
        if op == "get":
            return {"ok": True, "data": self._data.get(oid)}
        if op == "mget":
            return {"ok": True, "data": [self._data.get(o)
                                         for o in req["object_ids"]]}
        if op == "mevict":
            for o in req["object_ids"]:
                self._evict_object(o)
            return {"ok": True}
        if op == "mexists":
            return {"ok": True, "data": [o in self._data
                                         for o in req["object_ids"]]}
        if op == "exists":
            return {"ok": True, "data": oid in self._data}
        if op == "evict":
            self._evict_object(oid)
            return {"ok": True}
        if op == "incref":
            return {"ok": True,
                    "data": self.lifetime.incref(oid, req.get("n", 1))}
        if op == "decref":
            return {"ok": True,
                    "data": self.lifetime.decref(oid, req.get("n", 1))}
        if op == "mincref":
            n = req.get("n", 1)
            return {"ok": True, "data": [self.lifetime.incref(o, n)
                                         for o in req["object_ids"]]}
        if op == "mdecref":
            n = req.get("n", 1)
            return {"ok": True, "data": [self.lifetime.decref(o, n)
                                         for o in req["object_ids"]]}
        if op == "refcount":
            return {"ok": True, "data": self.lifetime.refs.get(oid, 0)}
        if op == "touch":
            return {"ok": True, "data": self._touch(oid, req.get("ttl"))}
        if op == "mtouch":
            ttl = req.get("ttl")
            return {"ok": True, "data": [self._touch(o, ttl)
                                         for o in req["object_ids"]]}
        if op == "stats":
            return {"ok": True, "data": {"n": len(self._data),
                                         "n_ops": self._n_ops,
                                         **self.lifetime.stats(),
                                         **self.waiters.stats(),
                                         **self.streams.stats(),
                                         "peers": list(self._peers)}}
        return {"ok": False, "error": f"bad op {op!r}"}

    async def _local_async(self, req: dict) -> dict:
        """Ops that may PARK until a producer acts: futures ``wait`` and
        stream ``s_next``.  Runs on a task (client API) or a spawned
        peer-request task, so parked waits complete out of order behind
        faster ops.  Responses are in-band (``data`` bytes in the map) —
        the caller converts to a raw reply for API clients."""
        self._n_ops += 1
        op = req["op"]
        if op == "wait":
            oid = req.get("object_id")
            data = await self.waiters.wait_for(
                oid, self._data.get, float(req.get("timeout", 60.0)))
            if data is None:
                return {"ok": False, "timeout": True,
                        "error": f"wait timed out on {oid!r}"}
            return {"ok": True, "data": data}
        if op == "s_next":
            topic, pos = req["topic"], int(req["i"])
            st = await self.streams.wait_item(
                topic, pos, float(req.get("timeout", 60.0)))
            if st is None:
                return {"ok": False, "timeout": True,
                        "error": f"stream {topic!r} item {pos} timed out"}
            if st["count"] > pos:
                key = stream_item_key(topic, pos)
                data = self._data.get(key)
                out = {"ok": True, "data": data,
                       "available": st["count"], "closed": st["closed"]}
                if data is None:
                    out["missing"] = True
                elif req.get("consume", True):
                    self.lifetime.decref(key)
                return out
            return {"ok": True, "data": None, "end": True,
                    "available": st["count"], "closed": True}
        if op == "s_next2":
            # blocking group take (delivery does not release the payload
            # reference — the group acks separately)
            topic, group = req["topic"], req["group"]
            got = await self.streams.wait_take(
                topic, group, float(req.get("timeout", 60.0)))
            if got is None:
                return {"ok": False, "timeout": True,
                        "error": f"stream {topic!r} group {group!r} "
                                 f"timed out"}
            st = self.streams.state(topic)
            if got == "end":
                return {"ok": True, "data": None, "end": True,
                        "available": st["count"], "closed": True}
            out = {"ok": True, "i": got, "data": None,
                   "meta": self.streams.meta.get(topic, {}).get(got) or {},
                   "available": st["count"], "closed": st["closed"]}
            if req.get("payload", True):
                data = self._data.get(stream_item_key(topic, got))
                out["data"] = data
                if data is None:
                    out["missing"] = True
            return out
        return self._local(req)

    # ------------------------------------------------------------------
    # relay client
    # ------------------------------------------------------------------
    async def _relay_connect(self) -> None:
        reader, writer = await asyncio.open_connection(self.relay_host,
                                                       self.relay_port)
        self._relay_writer = writer
        writer.write(_frame({"type": "register", "uuid": self.uuid,
                             "meta": {"peer_host": self._peer_host,
                                      "peer_port": self._peer_port}}))
        await writer.drain()
        msg = await _read(reader)
        if not msg or msg.get("type") != "registered":
            raise RuntimeError(
                f"relay handshake failed: expected 'registered', got {msg!r}")
        self.uuid = msg["uuid"]
        asyncio.create_task(self._relay_loop(reader))

    async def _relay_loop(self, reader: asyncio.StreamReader) -> None:
        while True:
            msg = await _read(reader)
            if msg is None:
                return
            mtype = msg.get("type")
            if mtype == "offer":
                # remote endpoint wants to peer with us: answer with our
                # listening address (our "session description")
                await self._relay_send({
                    "type": "answer", "target": msg["source"],
                    "rid": msg.get("rid"),
                    "sdp": {"host": self._peer_host, "port": self._peer_port},
                })
            elif mtype in ("answer", "error", "endpoints"):
                q = self._relay_replies.get(str(msg.get("rid")))
                if q is not None:
                    q.put_nowait(msg)

    async def _relay_send(self, msg: dict) -> None:
        if self._relay_writer is None:
            raise RuntimeError("relay not connected (no relay writer)")
        self._relay_writer.write(_frame(msg))
        await self._relay_writer.drain()

    async def _relay_request(self, msg: dict, timeout: float = 30.0) -> dict:
        self._rid += 1
        rid = f"r{self._rid}"
        q: asyncio.Queue = asyncio.Queue()
        self._relay_replies[rid] = q
        try:
            await self._relay_send(dict(msg, rid=rid))
            return await asyncio.wait_for(q.get(), timeout)
        finally:
            self._relay_replies.pop(rid, None)

    # ------------------------------------------------------------------
    # peering
    # ------------------------------------------------------------------
    async def _get_peer(self, target: str) -> PeerChannel:
        chan = self._peers.get(target)
        if chan is not None and chan.alive:
            return chan
        # concurrent requests to a cold peer share ONE dial — without this,
        # racing _forward tasks would each open (and then leak) a channel
        dial = self._peer_dials.get(target)
        if dial is None:
            dial = asyncio.ensure_future(self._dial_peer(target))
            self._peer_dials[target] = dial
            dial.add_done_callback(
                lambda _t: self._peer_dials.pop(target, None))
        return await dial

    async def _dial_peer(self, target: str) -> PeerChannel:
        # offer/answer via relay (Fig 4 steps 1-4), then direct dial (step 5)
        reply = await self._relay_request({
            "type": "offer", "target": target,
            "sdp": {"host": self._peer_host, "port": self._peer_port},
        })
        if reply.get("type") == "error":
            raise ConnectionError(reply.get("error"))
        sdp = reply["sdp"]
        reader, writer = await asyncio.open_connection(sdp["host"], sdp["port"])
        writer.write(_frame({"kind": "hello", "uuid": self.uuid}))
        await writer.drain()
        chan = PeerChannel(reader, writer, self.throttle_bps, self.throttle_rtt)
        self._peers[target] = chan
        asyncio.create_task(self._peer_read_loop(target, chan))
        return chan

    async def _peer_request_task(self, msg: dict, chan: PeerChannel) -> None:
        """One peer-forwarded PARKING op (wait/s_next): runs on its own
        task so a wait parked for a producer never stalls the peer
        channel's read loop (other requests keep flowing)."""
        try:
            resp = await self._local_async(msg)
        except Exception as e:  # noqa: BLE001 - peer must get a response
            resp = {"ok": False, "error": str(e)}
        resp.update(rid=msg["rid"], kind="resp")
        try:
            await chan.send(resp)
        except (ConnectionError, OSError):
            pass

    async def _peer_read_loop(self, peer_uuid: str, chan: PeerChannel) -> None:
        tasks: set[asyncio.Task] = set()
        try:
            while True:
                msg = await _read(chan.reader)
                if msg is None:
                    chan.close()
                    if self._peers.get(peer_uuid) is chan:
                        del self._peers[peer_uuid]
                    return
                if msg.get("kind") == "req":
                    if msg.get("op") in _PARKING_OPS:
                        task = asyncio.create_task(
                            self._peer_request_task(msg, chan))
                        tasks.add(task)
                        task.add_done_callback(tasks.discard)
                        continue
                    resp = self._local(msg)
                    resp.update(rid=msg["rid"], kind="resp")
                    await chan.send(resp)
                elif msg.get("kind") == "resp":
                    chan.dispatch_response(msg)
        finally:
            for task in tasks:
                task.cancel()

    async def _peer_accept(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        hello = await _read(reader)
        if not hello or hello.get("kind") != "hello":
            writer.close()
            return
        peer_uuid = hello["uuid"]
        chan = PeerChannel(reader, writer, self.throttle_bps, self.throttle_rtt)
        self._peers[peer_uuid] = chan
        await self._peer_read_loop(peer_uuid, chan)

    # ------------------------------------------------------------------
    # client API server
    # ------------------------------------------------------------------
    # Clients are KVClient instances speaking the seq-tagged pipelined
    # protocol of :mod:`repro.core.kv_tcp`: every request carries "seq",
    # every response echoes it, and responses may be written out of order.
    # Local ops are answered inline (they are synchronous dict accesses);
    # peer-forwarded ops run on tasks so one WAN round trip never stalls
    # the other requests pipelined on the same connection.

    @staticmethod
    async def _respond(writer: asyncio.StreamWriter, lock: asyncio.Lock,
                       resp: dict, raw: tuple | None = None) -> None:
        async with lock:
            writer.write(_frame(resp))
            if raw:
                for blob in raw:
                    writer.write(blob)
            await writer.drain()

    # response fields relayed verbatim from a peer (futures/stream ops
    # carry park-outcome metadata beyond the classic ok/data/error)
    _RELAY_FIELDS = ("ok", "data", "error", "timeout", "end", "available",
                     "closed", "missing", "i", "meta", "seqs", "metas")

    async def _forward(self, req: dict, writer: asyncio.StreamWriter,
                       lock: asyncio.Lock, target: str,
                       raw_reply: bool) -> None:
        seq = req.get("seq")
        try:
            chan = await self._get_peer(target)
            peer_timeout = 120.0
            if req.get("op") in _PARKING_OPS:
                # the remote end parks up to the op's own timeout; give the
                # channel round trip headroom beyond it
                peer_timeout = float(req.get("timeout", 60.0)) + 30.0
            r = await chan.request({k: v for k, v in req.items()
                                    if k not in ("endpoint_id", "seq")},
                                   timeout=peer_timeout)
            resp = {k: v for k, v in r.items()
                    if k in self._RELAY_FIELDS}
        except Exception as e:  # noqa: BLE001 - the client must get a
            # response for this seq; an escaping exception would kill the
            # task silently and leave the request hanging client-side
            resp = {"ok": False, "error": str(e)}
        raw: tuple | None = None
        if raw_reply and resp.get("ok"):
            data = resp.pop("data", None)
            if req.get("op") in ("mget", "s_fetch"):   # forwarded batch:
                datas = data or []                     # blob list
                resp["raws"] = [-1 if d is None else len(d) for d in datas]
                raw = tuple(d for d in datas if d is not None)
            else:
                resp["raw"] = -1 if data is None else len(data)
                raw = (data,) if data is not None else None
        if seq is not None:
            resp["seq"] = seq
        try:
            await self._respond(writer, lock, resp, raw)
        except (ConnectionError, OSError):
            pass

    async def _local_parked(self, req: dict, writer: asyncio.StreamWriter,
                            lock: asyncio.Lock) -> None:
        """A local PARKING op from an API client: await it on this task
        (pipelined requests overtake it) and answer get2-style (raw)."""
        seq = req.get("seq")
        try:
            resp = await self._local_async(req)
        except Exception as e:  # noqa: BLE001 - client must get a response
            resp = {"ok": False, "error": str(e)}
        raw: tuple | None = None
        if resp.get("ok"):
            data = resp.pop("data", None)
            resp["raw"] = -1 if data is None else len(data)
            raw = (data,) if data is not None else None
        if seq is not None:
            resp["seq"] = seq
        try:
            await self._respond(writer, lock, resp, raw)
        except (ConnectionError, OSError):
            pass

    async def _client_loop(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        send_lock = asyncio.Lock()
        tasks: set[asyncio.Task] = set()

        def spawn(coro) -> None:
            task = asyncio.create_task(coro)
            tasks.add(task)
            task.add_done_callback(tasks.discard)

        try:
            while True:
                req = await _read(reader)
                if req is None:
                    break
                op = req.get("op")
                seq = req.get("seq")
                raw: tuple | None = None
                if op == "shutdown":
                    await self._respond(writer, send_lock,
                                        {"ok": True, "seq": seq})
                    self._shutdown.set()
                    break
                if op == "uuid":
                    resp = {"ok": True, "data": self.uuid}
                elif op == "put2":
                    # out-of-band payload, consumed here in stream order;
                    # puts always target the local endpoint
                    nbytes = int(req["nbytes"])
                    if not 0 <= nbytes <= MAX_FRAME:
                        # cannot resync without consuming the payload:
                        # report the reason, then drop the connection
                        await self._respond(writer, send_lock, {
                            "ok": False, "seq": seq,
                            "error": f"bad payload size: {nbytes}"})
                        break
                    try:
                        data = (await reader.readexactly(nbytes)
                                if nbytes else b"")
                    except (asyncio.IncompleteReadError,
                            ConnectionResetError):
                        break
                    oid = req.get("object_id") or req.get("key")
                    resp = self._local({"op": "put", "object_id": oid,
                                        "data": data})
                elif op == "mput2":
                    # a whole batch in one exchange: blobs arrive back to
                    # back after the header (always local, like put2)
                    sizes = [int(n) for n in req["nbytes"]]
                    if sum(sizes) > MAX_FRAME or any(n < 0 for n in sizes):
                        await self._respond(writer, send_lock, {
                            "ok": False, "seq": seq,
                            "error": f"bad payload sizes: {sum(sizes)}"})
                        break
                    try:
                        payload = (await reader.readexactly(sum(sizes))
                                   if sum(sizes) else b"")
                    except (asyncio.IncompleteReadError,
                            ConnectionResetError):
                        break
                    oids = req.get("object_ids") or req.get("keys")
                    mv = memoryview(payload)
                    off = 0
                    if self.persist:
                        for oid, n in zip(oids, sizes):
                            self._local({"op": "put", "object_id": oid,
                                         "data": bytes(mv[off:off + n])})
                            off += n
                    else:
                        for oid, n in zip(oids, sizes):
                            self._store_obj(oid, bytes(mv[off:off + n]))
                            off += n
                        self._n_ops += len(oids)
                    resp = {"ok": True}
                elif op == "s_append":
                    # out-of-band item payload; appends always target the
                    # local endpoint (the topic lives where it is produced)
                    nbytes = int(req["nbytes"])
                    if not 0 <= nbytes <= MAX_FRAME:
                        await self._respond(writer, send_lock, {
                            "ok": False, "seq": seq,
                            "error": f"bad payload size: {nbytes}"})
                        break
                    try:
                        data = (await reader.readexactly(nbytes)
                                if nbytes else b"")
                    except (asyncio.IncompleteReadError,
                            ConnectionResetError):
                        break
                    try:
                        resp = self._local({"op": "s_append",
                                            "topic": req["topic"],
                                            "data": data,
                                            "ttl": req.get("ttl"),
                                            "meta": req.get("meta")})
                    except Exception as e:  # noqa: BLE001 - e.g. a late
                        # append to a closed stream: an error RESPONSE, not
                        # a torn-down connection for every pipelined op
                        resp = {"ok": False, "error": str(e)}
                elif op == "s_fetch":
                    # batch group take: blobs answer mget2-style (raws)
                    target = req.get("endpoint_id") or self.uuid
                    if target != self.uuid:
                        spawn(self._forward(req, writer, send_lock, target,
                                            raw_reply=True))
                        continue
                    resp = self._local(req)
                    datas = resp.pop("data", None)
                    if resp.get("ok") and datas is not None:
                        resp["raws"] = [-1 if d is None else len(d)
                                        for d in datas]
                        raw = tuple(d for d in datas if d is not None)
                elif op in _PARKING_OPS:
                    # wait / s_next park until a producer acts: always on a
                    # task, local or forwarded, so pipelined requests on
                    # this connection overtake them
                    target = req.get("endpoint_id") or self.uuid
                    if target != self.uuid:
                        spawn(self._forward(req, writer, send_lock, target,
                                            raw_reply=True))
                    else:
                        spawn(self._local_parked(req, writer, send_lock))
                    continue
                elif op == "mget2":
                    oids = req.get("object_ids") or req.get("keys")
                    target = req.get("endpoint_id") or self.uuid
                    if target != self.uuid:
                        spawn(self._forward(
                            dict(req, op="mget", object_ids=oids), writer,
                            send_lock, target, raw_reply=True))
                        continue
                    datas = [self._data.get(o) for o in oids]
                    self._n_ops += 1
                    resp = {"ok": True,
                            "raws": [-1 if d is None else len(d)
                                     for d in datas]}
                    raw = tuple(d for d in datas if d is not None)
                elif op == "get2":
                    oid = req.get("object_id") or req.get("key")
                    target = req.get("endpoint_id") or self.uuid
                    if target != self.uuid:
                        spawn(self._forward(
                            dict(req, op="get", object_id=oid), writer,
                            send_lock, target, raw_reply=True))
                        continue
                    data = self._data.get(oid)
                    self._n_ops += 1
                    resp = {"ok": True,
                            "raw": -1 if data is None else len(data)}
                    raw = (data,) if data is not None else None
                else:
                    target = req.get("endpoint_id") or self.uuid
                    if target != self.uuid:
                        spawn(self._forward(req, writer, send_lock, target,
                                            raw_reply=False))
                        continue
                    resp = self._local(req)
                if seq is not None:
                    resp["seq"] = seq
                await self._respond(writer, send_lock, resp, raw)
        finally:
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            writer.close()

    # ------------------------------------------------------------------
    async def run(self, api_host: str, api_port: int,
                  ready_file: str | None) -> None:
        peer_server = await asyncio.start_server(self._peer_accept,
                                                 "127.0.0.1", 0,
                                                 limit=STREAM_LIMIT)
        self._peer_port = peer_server.sockets[0].getsockname()[1]
        await self._relay_connect()
        api_server = await asyncio.start_server(self._client_loop,
                                                api_host, api_port,
                                                limit=STREAM_LIMIT)
        actual = api_server.sockets[0].getsockname()[1]
        if ready_file:
            tmp = Path(ready_file + ".tmp")
            # one-time startup write, no clients yet  # lint: blocking-ok
            tmp.write_text(f"{api_host}:{actual}:{os.getpid()}:{self.uuid}")
            tmp.replace(ready_file)

        async def _expiry_backstop() -> None:
            while True:          # idle endpoints must still expire leases
                await asyncio.sleep(LifetimeTable.SWEEP_INTERVAL)
                self.lifetime.maybe_sweep()

        sweeper = asyncio.create_task(_expiry_backstop())
        try:
            async with peer_server, api_server:
                await self._shutdown.wait()
        finally:
            sweeper.cancel()
        # drop peer channels so remote ends re-establish later (paper: the
        # connection is re-established if lost for any reason)
        for chan in self._peers.values():
            chan.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--relay", required=True, help="host:port of relay server")
    ap.add_argument("--uuid", default=None)
    ap.add_argument("--api-host", default="127.0.0.1")
    ap.add_argument("--api-port", type=int, default=0)
    ap.add_argument("--persist-dir", default=None)
    ap.add_argument("--throttle-bps", type=float, default=None)
    ap.add_argument("--throttle-rtt", type=float, default=0.0)
    ap.add_argument("--ready-file", default=None)
    args = ap.parse_args()
    rhost, rport = args.relay.rsplit(":", 1)
    ep = Endpoint(uuid=args.uuid, relay_host=rhost, relay_port=int(rport),
                  persist_dir=args.persist_dir,
                  throttle_bps=args.throttle_bps,
                  throttle_rtt=args.throttle_rtt)
    asyncio.run(ep.run(args.api_host, args.api_port, args.ready_file))


if __name__ == "__main__":
    main()
