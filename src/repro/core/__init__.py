"""ProxyStore core: the paper's contribution.

Public API mirrors the paper's usage (Listing 1):

    from repro.core import Store
    from repro.core.connectors import FileConnector

    store = Store("my-store", FileConnector("/tmp/psj"))
    p = store.proxy(obj)          # lightweight, pickles to ~200 bytes
    consume(p)                    # resolves just-in-time, transparently
"""
from repro.core.proxy import (OwnedProxy, Proxy, ProxyResolveError, borrow,
                              clone, extract, get_factory, into_owned,
                              is_proxy, is_resolved, release, resolve)
from repro.core.arena import Arena, ArenaPool
from repro.core.serialize import (Frame, as_segments, copy_segments_into,
                                  deserialize, frame_nbytes, join_frame,
                                  serialize, serialize_v1)
from repro.core.connector import BaseConnector, Connector, Key, StreamItem
from repro.core.store import (ProxyFuture, ProxyStream, Store, StoreConfig,
                              StoreFactory, StreamProducer, get_store,
                              get_or_create_store, maybe_proxy,
                              register_store, resolve_async, unregister_store)
from repro.core.multi import MultiConnector, NoConnectorMatch, Policy
from repro.core.fabric import (FabricPipeline, HashRing, ShardHealth,
                               ShardedConnector)

__all__ = [
    "Proxy", "OwnedProxy", "ProxyResolveError", "borrow", "clone",
    "into_owned", "release", "extract", "get_factory", "is_proxy",
    "is_resolved", "resolve", "serialize", "serialize_v1", "deserialize",
    "Arena", "ArenaPool", "Frame", "as_segments", "copy_segments_into",
    "frame_nbytes", "join_frame", "BaseConnector",
    "Connector", "Key", "StreamItem", "Store", "StoreConfig", "StoreFactory",
    "ProxyFuture", "ProxyStream", "StreamProducer", "get_store",
    "get_or_create_store", "maybe_proxy", "register_store", "resolve_async",
    "unregister_store", "MultiConnector", "NoConnectorMatch", "Policy",
    "FabricPipeline", "HashRing", "ShardHealth", "ShardedConnector",
]
