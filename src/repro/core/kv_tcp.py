"""Asyncio TCP key-value server + pipelined multiplexed blocking client.

Plays two roles from the paper:

* the per-node storage servers spawned by the ZMQ/Margo/UCX connectors
  (§4.1.3: "these connectors act as interfaces to these spawned servers"),
* the Redis-style standalone hybrid store (§4.1.2) when started with
  ``--persist-dir`` (write-through to disk, reload on restart).

Wire format
===========

Every message is a frame: ``4-byte big-endian length | msgpack map``.  Some
ops carry raw payload bytes *out of band*, immediately after the frame that
announces them, so multi-segment PSJ2 frames never pay a join or msgpack
copy.

**Multiplexing.** Every request map carries a client-assigned ``"seq"``
(monotonic per connection); every response echoes it.  Many requests from
one client share a single connection in flight, and the server may complete
them **out of order** (slow ops — disk persistence, ``sleep`` — are handled
on background tasks / an executor while fast in-memory ops overtake them).
The client's background reader thread matches responses to per-request
``Future``s by ``seq``.  Out-of-band payload bytes are written atomically
with their announcing frame (single writer lock on each side), so the byte
stream remains parseable even when frames interleave.

Requests (msgpack maps; ``seq`` omitted below for brevity):

* ``{"op": put|get|exists|evict|mput|mget|ping|stats|sleep|shutdown, ...}``
  — in-band ops; ``put``/``mput`` carry ``data``/``blobs`` inside the map.
* ``put2``: ``{"op": "put2", "key": k, "nbytes": n}`` followed by ``n`` raw
  bytes — the client gather-writes frame segments straight onto the socket
  (``sendmsg``/writev-style), the server reads them into one buffer.
* ``mput2``: ``{"op": "mput2", "keys": [...], "nbytes": [n0, n1, ...]}``
  followed by ``sum(n_i)`` raw bytes (the blobs back to back) — a whole
  batch in one exchange, gather-written with no per-blob copies.
* ``get2``: response ``{"ok": True, "raw": n}`` (-1 = missing) followed by
  ``n`` raw bytes — received into a preallocated buffer and surfaced as a
  writable memoryview, ready for zero-copy deserialization.
* ``mget2``: ``{"op": "mget2", "keys": [...]}`` — response
  ``{"ok": True, "raws": [n0, n1, ...]}`` (-1 = missing) followed by the
  present blobs back to back; the client receives them into one
  preallocated buffer and returns per-blob memoryview slices.
* ``sleep``: ``{"op": "sleep", "s": seconds}`` — completes off the read
  loop; exists so tests and benchmarks can observe out-of-order completion
  deterministically.

**Lifecycle ops** (the ownership subsystem; see ``repro.core.store`` for the
client-side OwnedProxy/borrow model built on top):

* ``incref``: ``{"op": "incref", "key": k, "n": 1}`` — make ``k`` a
  *refcounted* key and add ``n`` references; responds with the new count.
* ``decref``: ``{"op": "decref", "key": k, "n": 1}`` — drop ``n``
  references; when the count reaches zero the object is evicted (exactly
  once — the count entry is removed atomically with the eviction).  A
  decref on a key with NO count entry is the legacy fire-and-forget evict
  (hard evict, count 0) so pre-ownership proxies keep their semantics.
* ``refcount``: ``{"op": "refcount", "key": k}`` — current count (0 if
  the key is not refcounted).
* ``touch``: ``{"op": "touch", "key": k, "ttl": seconds}`` — set/refresh a
  TTL lease: the key is evicted (and its references cleared) once ``ttl``
  seconds pass without another touch, bounding leaks from crashed
  reference holders.  ``ttl`` of None/<=0 clears the lease.
* ``mincref``/``mdecref``/``mtouch``: batched variants over ``keys``
  (one exchange for a whole proxy fan-out).

Lease expiry is *lazy*: a time-gated sweep runs at the top of request
handling (so even servers driven directly through ``handle`` expire keys)
plus a periodic backstop task on the serving event loop.  All count/lease
mutations happen in synchronous handler sections on the single event loop,
so incref/decref/evict interleavings from any number of connections are
atomic — this is what fixes the multi-consumer evict race.  All lease and
deadline arithmetic uses ``time.monotonic()``: TTLs are relative on the
wire and a wall-clock (NTP) step can neither reap live leased keys nor
stall the sweep.

**Futures ops** (communicate data before it exists; see
``repro.core.store`` for the ProxyFuture built on top):

* ``wait``: ``{"op": "wait", "key": k, "timeout": s}`` — a ``get2`` that
  *parks* until the key's ``put2`` (or any put) lands, then responds
  exactly like ``get2`` (``raw`` + out-of-band bytes).  Parked waits
  complete out of order like ``sleep`` does: later requests on the same
  connection overtake them.  On timeout the response is
  ``{"ok": False, "timeout": True, "error": ...}``.  Any number of waiters
  (across connections) are released by one put.
* ``mwait``: ``{"op": "mwait", "keys": [...], "timeout": s}`` — wait for
  ALL keys under one shared deadline; responds like ``mget2`` (``raws`` +
  blobs back to back, -1 for keys that never arrived, with
  ``"timeout": True`` set if any are missing).

**Stream ops** (per-topic append/consume with an end-of-stream marker):

* ``s_append``: ``{"op": "s_append", "topic": t, "nbytes": n, "ttl": ...}``
  followed by ``n`` raw bytes — stores the item under the derived key
  ``stream_item_key(t, seq)`` with ONE reference (refcount-integrated:
  consuming the item decrefs it, so consumed items are evicted exactly
  once, like the ownership subsystem's ephemerals); responds with the
  item's sequence number.  ``ttl`` optionally leases the item so an
  abandoned stream cannot leak.
* ``s_next``: ``{"op": "s_next", "topic": t, "i": i, "timeout": s}``
  (the stream position rides as ``"i"`` — ``"seq"`` is the connection's
  multiplexing tag) —
  parks until item ``i`` exists or the stream closes; item responses are
  ``get2``-style (``raw`` + bytes) and additionally carry ``"available"``
  (total appended count — the client batch-prefetches the rest via plain
  ``mget2``/``mdecref`` on derived keys) and ``"closed"``.  By default the
  served item is decref'd server-side (consumed); pass ``"consume": False``
  to peek.  Past the end of a closed stream the response is
  ``{"ok": True, "raw": -1, "end": True}``.
* ``s_close``: ``{"op": "s_close", "topic": t}`` — sets the end-of-stream
  marker and releases every parked consumer.
* ``s_stat``: ``{"op": "s_stat", "topic": t}`` — ``{"count", "closed"}``
  plus, for topics with consumer groups, ``{"groups", "limit",
  "buffered"}`` — without blocking.

**Pub/sub group ops** (broker mode: named consumer groups with independent
cursors, per-group acks, server-side filters, credit-based backpressure —
the arXiv:2407.01764 "proxy-on-publish" event-stream pattern):

* ``s_sub``: ``{"op": "s_sub", "topic": t, "group": g, "start":
  "new"|"begin", "filter": spec}`` — create consumer group ``g``
  (idempotent: re-subscribing returns the existing group's state).  With
  ``start="begin"`` the group adopts every retained item; later groups
  incref retained items so each holds its own payload reference.
  ``filter`` is a declarative spec (see :mod:`repro.stream.filters`)
  evaluated server-side against event *metadata*: events a group filters
  out never enter its queue and never touch the payload path.
* ``s_append`` extension: ``"meta"`` (a small msgpack map) rides in the
  request header.  On a topic with subscribed groups the payload is stored
  with ONE reference per matching group — bytes cross the data plane once
  regardless of fanout, and the item is evicted when the LAST group acks.
  An event every group filters out is never stored at all (zero payload
  work).  Topics without groups keep the legacy single-reference
  behavior.  When an ``s_limit`` bound is set and the topic's buffer of
  unacked events is full, ``s_append`` PARKS until consumer acks free
  credits (timeout → ``{"ok": False, "timeout": True}``).
* ``s_next2``: ``{"op": "s_next2", "topic": t, "group": g, "timeout": s,
  "payload": bool}`` — park until an event is deliverable to the group;
  responds ``get2``-style with ``"i"`` (the event's seq) and ``"meta"``
  in-band.  ``payload=False`` delivers metadata only (the payload bytes
  are never served — metrics-tap consumers).  Delivery does NOT release
  the payload reference; the group acks separately.
* ``s_fetch``: ``{"op": "s_fetch", "topic": t, "group": g, "n": k,
  "payload": bool}`` — non-blocking batch take of up to ``k`` deliverable
  events in ONE exchange (``seqs`` + ``metas`` in-band, blobs
  ``mget2``-style out-of-band).
* ``s_ack``: ``{"op": "s_ack", "topic": t, "group": g, "seqs": [...]}`` —
  per-group ack: releases each event's group reference (payload evicted
  after the last group acks) and frees backpressure credits.  Idempotent
  (only seqs the group actually holds unacked are applied).
* ``s_requeue``: ``{"op": "s_requeue", "topic": t, "group": g, "seqs":
  [...]}`` — return delivered-but-unprocessed events to the group's queue
  (redelivered in sequence order); how a consumer hands back prefetched
  items on ``close()`` instead of leaking them.
* ``s_unsub``: drop the group, releasing its outstanding references.
* ``s_limit``: ``{"op": "s_limit", "topic": t, "limit": n}`` — bound the
  per-topic buffer of unacked events (``limit`` falsy clears the bound).

**Durability ops** (server-side replication — the sharded fabric's
durable-by-default plane):

* ``put2``/``mput2`` extension — **chain replication**: ``"chain":
  [addr, ...]`` makes the receiving shard (the key's ring primary)
  forward the stored bytes to each listed successor over a shard-to-shard
  connection, awaiting a per-hop ack, before responding.  The client
  uploads ONE copy instead of R; the response carries ``"chain_acks"``
  and, for successors that could not be reached, ``"chain_errors":
  [addr, ...]`` so the caller can queue a repair.  Forwarded copies are
  plain ``mput2`` (no ``chain`` field), so a forward never re-forwards.
  ``"refs"``/``"ttl"`` on a ``put2`` install refcount/lease state with
  the bytes (hinted-handoff replay ships lifecycle state this way).
* **Hinted handoff**: ``"hint_for": addr`` on a put records, on the shard
  that accepted it, that ``addr`` (the suspect intended owner) is owed
  the key.  ``hints`` dumps the pending hint map; ``hint_replay``
  ``{"op": "hint_replay", "owner": addr}`` re-puts every hinted key —
  bytes + current refcount + remaining lease — to the recovered owner
  and drops the hints (failed replays are kept for a later attempt).
* ``s_chain``: ``{"op": "s_chain", "topic": t, "chain": [addr, ...]}`` —
  install the topic's replica chain.  Every subsequent group-state
  mutation (subscribe, take, ack, requeue, limit, close) pushes a
  cursor snapshot to the chain (coalesced, asynchronous), and every
  ``s_append`` forwards the payload AND pushes the snapshot
  *synchronously* before acking — a committed append is on every chain
  member, so a failover loses no committed events (at-least-once: the
  crash window re-delivers, never skips).
* ``s_snap``: ``{"op": "s_snap", "topic": t}`` — the topic's full broker
  state (cursors, group queues/unacked sets, filters, metadata, owner
  refcounts, limits, delivery counts) as one msgpack map.
* ``s_restore``: ``{"op": "s_restore", "topic": t, "state": snap}`` —
  install a snapshot wholesale, reconciling payload-key refcounts with
  the replicated owner counts and pruning payloads no group retains.
* ``s_drop``: ``{"op": "s_drop", "topic": t}`` — remove the topic's
  broker state and evict its payload keys (rebalance uses snap → copy →
  restore → drop to move a topic's home shard).

**Dead-letter queues**: ``s_limit`` accepts ``"max_deliveries": n``.
The table counts deliveries per (group, seq); an event requeued after its
n-th delivery is not redelivered — it moves to the ``<topic>.dlq`` topic
with the original metadata plus ``{"dlq": {"topic", "group", "seq",
"deliveries", "reason"}}``, and the group's claim on the original payload
is released.  DLQ topics are ordinary topics: subscribe a group (e.g. a
``payload=False`` tap) to observe failures.

Responses: ``{"ok": bool, "seq": int, "data": ..., "error": str}`` plus the
``raw``/``raws`` out-of-band markers above.

The server is a single asyncio loop (as the paper's PS-endpoints are), but
per-request handling runs on tasks: persistence writes go through
``run_in_executor`` so one persisting client never stalls the other
connections, and batched clients stream requests back to back instead of
paying one round trip each.

**Copy-free ingest.** The server speaks :class:`KVIngestProtocol`, an
``asyncio.BufferedProtocol``: announced out-of-band payloads are
``recv_into``'d directly into their *final* buffer (the exact bytearray
the data map will hold), so a ``put2`` pays exactly one kernel→user copy —
no StreamReader staging buffer, no ``bytes()`` re-copy.  ``mput2`` stores
per-key *views* sliced from the one received batch buffer, and ``get2``/
``mget2`` responses gather-write those stored buffers without joining.
The pipelined client mirrors this: responses' raw payloads are received
into preallocated per-blob buffers (``recv_into``) surfaced as writable
memoryviews, ready for zero-copy deserialization.

**Transports.** ``host`` is either a TCP host name or a Unix-domain
address written ``unix:/path/to.sock`` (``port`` is then ignored).  Same-
host deployments — the sharded fabric's local shards in particular —
should prefer UDS: on loopback it moves bytes ~2× faster than the TCP
stack.  Both transports speak the identical frame protocol.

**Failure semantics** (the sharded fabric's substrate): ops in
:data:`IDEMPOTENT_OPS` (reads, existence/metadata probes, absolute-value
lease ops, hard evicts) are re-issued automatically through the
transparent-reconnect path when a connection dies mid-request, governed
by a :class:`repro.distributed.fault_tolerance.RetryPolicy`.  Mutating
ops whose double-apply would corrupt state (``put2``/``mput2``,
``incref``/``decref``, ``s_append``, consuming ``s_next``) fail fast
with ``ConnectionError`` so the caller decides (the fabric fails a put
over to the key's replica set; a lone client surfaces the error).
``keyspace`` dumps keys + refcounts + lease remainders so a rebalance
can migrate lifecycle state along with the data.
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import contextlib
import itertools
import os
import socket
import struct
import subprocess
import sys
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from pathlib import Path
from typing import Any

import msgpack

from repro.distributed.fault_tolerance import RetryPolicy

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 31

# Ops safe to re-issue after a connection loss: reads, existence/metadata
# probes, absolute-value lease ops (touch sets, never increments), hard
# evicts (evicting twice == evicting once), parked waits, and diagnostics.
# Deliberately NOT here: put2/mput2 (a retried put could overtake a later
# put to the same key), incref/decref (double-applied deltas corrupt the
# count), s_append (a duplicate item under a second sequence number), and
# consuming s_next (the first attempt may already have consumed the item).
IDEMPOTENT_OPS = frozenset({
    "get", "get2", "mget", "mget2", "exists", "mexists", "refcount",
    "touch", "mtouch", "evict", "mevict", "s_stat", "s_close", "wait",
    "mwait", "ping", "stats", "keyspace", "sleep",
    # group ops: s_sub re-subscribe returns the existing group, s_unsub
    # twice == once, s_ack/s_requeue act only on seqs the group actually
    # holds unacked, s_limit sets an absolute bound.  NOT s_next2/s_fetch:
    # delivery moves events out of the group queue.
    "s_sub", "s_unsub", "s_ack", "s_requeue", "s_limit",
    # durability ops: s_snap is a read, s_restore installs an absolute
    # snapshot (restoring twice == once), s_chain sets an absolute chain,
    # s_drop twice == once, hints is a read.  NOT hint_replay: a replay
    # re-applies incref deltas on the owner.
    "s_snap", "s_restore", "s_chain", "s_drop", "hints",
})


def is_uds(host: str) -> bool:
    """True when ``host`` addresses a Unix-domain socket path."""
    return host.startswith("unix:") or host.startswith("/")


def uds_path(host: str) -> str:
    return host[5:] if host.startswith("unix:") else host
_IOV_MAX = 1024             # sendmsg segment cap per call (POSIX floor)
# asyncio's default 64 KB StreamReader limit causes pause/resume flow-
# control churn on every payload read and caps server ingest well below
# loopback bandwidth; large reads need a large buffer ceiling
STREAM_LIMIT = 8 * 1024 * 1024
_SOCKBUF = 4 * 1024 * 1024  # kernel socket buffers for MB-scale payloads


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------
async def read_frame(reader: asyncio.StreamReader) -> dict | None:
    try:
        header = await reader.readexactly(4)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    (length,) = _LEN.unpack(header)
    if length > MAX_FRAME:
        raise ValueError(f"frame too large: {length}")
    body = await reader.readexactly(length)
    return msgpack.unpackb(body, raw=False, strict_map_key=False)


def write_frame_sync(sock: socket.socket, msg: dict) -> None:
    body = msgpack.packb(msg, use_bin_type=True)
    sock.sendall(_LEN.pack(len(body)) + body)


def _byte_view(seg) -> memoryview | None:
    """Flat byte view of ``seg`` WITHOUT copying, or None when the view is
    non-contiguous (the caller gathers those once, never per segment)."""
    mv = memoryview(seg)
    if mv.format != "B" or mv.ndim != 1:
        try:
            mv = mv.cast("B")
        except TypeError:        # non-contiguous exotic view
            return None
    return mv


def _gather_views(segments) -> list[memoryview]:
    """Normalize segments to flat byte views.  Contiguous views pass
    through zero-copy; runs of non-contiguous ones are gathered into ONE
    buffer per run (a single copy total — never a copy per segment)."""
    out: list[memoryview] = []
    pending: list[memoryview] = []   # consecutive non-contiguous views

    def flush() -> None:
        if pending:
            # tobytes() is the one unavoidable gather of a scattered view;
            # a single view ships it directly, a run joins into one iovec
            parts = [p.tobytes() for p in pending]
            out.append(memoryview(parts[0] if len(parts) == 1
                                  else b"".join(parts)))
            pending.clear()

    for s in segments:
        v = _byte_view(s)
        if v is None:
            mv = memoryview(s)
            if mv.nbytes:
                pending.append(mv)
        else:
            flush()
            if v.nbytes:
                out.append(v)
    flush()
    return out


def send_segments_sync(sock: socket.socket, segments) -> None:
    """Gather-write raw payload segments with ``sendmsg`` (no user-space
    join): many small segments go out in single syscalls, ``_IOV_MAX`` at a
    time, with partial sends resumed mid-segment."""
    bufs = _gather_views(segments)
    while bufs:
        try:
            sent = sock.sendmsg(bufs[:_IOV_MAX])
        except InterruptedError:
            continue
        while sent:
            if bufs[0].nbytes <= sent:
                sent -= bufs[0].nbytes
                bufs.pop(0)
            else:
                bufs[0] = bufs[0][sent:]
                sent = 0


def read_frame_sync(sock: socket.socket) -> dict:
    header = _recv_exact(sock, 4)
    (length,) = _LEN.unpack(header)
    body = _recv_exact(sock, length)
    return msgpack.unpackb(body, raw=False, strict_map_key=False)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_exact_into(sock: socket.socket, view: memoryview) -> None:
    while view.nbytes:
        n = sock.recv_into(view)
        if not n:
            raise ConnectionError("peer closed connection")
        view = view[n:]


class _BufferedSock:
    """Buffered reads over a blocking socket for the client's reader
    thread: small frames coalesce into one ``recv_into`` per TCP burst
    instead of two ``recv`` syscalls per frame, while announced payloads
    drain the buffered prefix and then ``recv_into`` their final buffer
    directly — the client-side mirror of :class:`KVIngestProtocol`."""

    __slots__ = ("sock", "buf", "r", "w")

    def __init__(self, sock: socket.socket, size: int = 256 * 1024) -> None:
        self.sock = sock
        self.buf = bytearray(size)
        self.r = 0
        self.w = 0

    def _fill(self) -> None:
        if self.r == self.w:
            self.r = self.w = 0
        elif self.w == len(self.buf):
            live = self.buf[self.r:self.w]   # compact (slice copies: safe)
            self.buf[:len(live)] = live
            self.r, self.w = 0, len(live)
        n = self.sock.recv_into(memoryview(self.buf)[self.w:])
        if not n:
            raise ConnectionError("peer closed connection")
        self.w += n

    def read_view(self, n: int) -> memoryview:
        """A view of the next ``n`` bytes (valid until the next call)."""
        if n > len(self.buf):               # oversized frame: grow once
            new = bytearray(n)
            new[:self.w - self.r] = self.buf[self.r:self.w]
            self.w -= self.r
            self.r = 0
            self.buf = new
        while self.w - self.r < n:
            self._fill()
        v = memoryview(self.buf)[self.r:self.r + n]
        self.r += n
        return v

    def readinto(self, view: memoryview) -> None:
        """Fill ``view`` exactly: buffered prefix first, then straight
        ``recv_into`` the destination (no staging copy for the bulk)."""
        take = min(view.nbytes, self.w - self.r)
        if take:
            view[:take] = memoryview(self.buf)[self.r:self.r + take]
            self.r += take
            view = view[take:]
        if view.nbytes:
            _recv_exact_into(self.sock, view)


# ---------------------------------------------------------------------------
# lifecycle state machine (shared by KVServer and the PS-endpoint)
# ---------------------------------------------------------------------------
class LifetimeTable:
    """Per-key reference counts + TTL leases with a lazy, time-gated expiry
    sweep.  Mutations happen in the synchronous sections of a single-
    threaded server loop, so incref/decref/evict interleavings from any
    number of connections are atomic — the property that fixes the
    multi-consumer evict race.

    ``evict_fn`` performs the full eviction (data, persistence) and must
    call :meth:`drop` so lifecycle state dies with the object.
    """

    SWEEP_INTERVAL = 0.25         # min seconds between lazy lease sweeps

    def __init__(self, evict_fn) -> None:
        self.refs: dict[str, int] = {}       # refcounted keys -> count
        self.leases: dict[str, float] = {}   # key -> absolute expiry time
        self.n_expired = 0
        self.n_legacy_evicts = 0             # decrefs on unmanaged keys
        self._next_sweep = 0.0
        self._evict_fn = evict_fn

    def drop(self, key: str) -> None:
        """Forget lifecycle state for an evicted key."""
        self.refs.pop(key, None)
        self.leases.pop(key, None)

    def incref(self, key: str, n: int = 1) -> int:
        count = self.refs.get(key, 0) + int(n)
        self.refs[key] = count
        return count

    def decref(self, key: str, n: int = 1) -> int:
        count = self.refs.get(key)
        if count is None:
            # legacy fire-and-forget: a decref on an unmanaged key is the
            # old hard evict, so pre-ownership evict=True proxies still work
            self.n_legacy_evicts += 1
            self._evict_fn(key)
            return 0
        count -= int(n)
        if count > 0:
            self.refs[key] = count
            return count
        self._evict_fn(key)       # exactly once: drop() runs with the data
        return 0

    def touch(self, key: str, ttl) -> None:
        # monotonic, not wall-clock: TTLs are relative on the wire, and an
        # NTP step must not reap live leased keys or stall the sweep
        if ttl is None or float(ttl) <= 0:
            self.leases.pop(key, None)
        else:
            self.leases[key] = time.monotonic() + float(ttl)

    def sweep(self, now: float | None = None) -> int:
        """Evict every key whose lease has expired (refs cleared too: an
        expired lease means the reference holders are presumed dead)."""
        now = time.monotonic() if now is None else now
        self._next_sweep = now + self.SWEEP_INTERVAL
        if not self.leases:
            return 0
        expired = [k for k, t in self.leases.items() if t <= now]
        for k in expired:
            self._evict_fn(k)
        self.n_expired += len(expired)
        return len(expired)

    def maybe_sweep(self) -> None:
        if self.leases and time.monotonic() >= self._next_sweep:
            self.sweep()

    def stats(self) -> dict:
        return {"n_refcounted": len(self.refs),
                "n_leases": len(self.leases),
                "n_expired": self.n_expired,
                "n_legacy_evicts": self.n_legacy_evicts}

    def snapshot(self) -> dict[str, int]:
        """Copy of the full per-key refcount table (the sanitizer's
        close-time cross-check reads this)."""
        return dict(self.refs)


# ---------------------------------------------------------------------------
# futures + streams state machines (shared by KVServer and the PS-endpoint)
# ---------------------------------------------------------------------------
def stream_item_key(topic: str, seq: int) -> str:
    """Derived storage key of stream item ``seq`` of ``topic`` — shared
    between server and client so consumers can batch-prefetch ready items
    with plain ``mget2``/``mdecref`` exchanges."""
    return f"@s:{topic}:{seq}"


def dlq_topic(topic: str) -> str:
    """Dead-letter topic of ``topic``.  A DLQ is an ordinary topic (the
    fabric homes it on its parent topic's shard); events land here with a
    ``"dlq"`` metadata record once redelivered past ``max_deliveries``."""
    return f"{topic}.dlq"


class WaiterTable:
    """key -> parked asyncio futures.  ``wake(key)`` (called wherever a put
    lands) releases every waiter; each re-checks the data map, so a racing
    evict simply re-parks the waiter until its deadline."""

    def __init__(self) -> None:
        self.waiters: dict[str, list[asyncio.Future]] = {}

    def wake(self, key: str) -> None:
        for fut in self.waiters.pop(key, ()):  # noqa: B020 - snapshot pop
            if not fut.done():
                fut.set_result(None)

    async def wait_for(self, key: str, present_fn, timeout: float,
                       deadline: float | None = None):
        """Park until ``present_fn(key)`` returns non-None or the deadline
        passes; returns the value or None on timeout."""
        loop = asyncio.get_running_loop()
        if deadline is None:
            deadline = loop.time() + float(timeout)
        while True:
            value = present_fn(key)
            if value is not None:
                return value
            remaining = deadline - loop.time()
            if remaining <= 0:
                return None
            fut = loop.create_future()
            self.waiters.setdefault(key, []).append(fut)
            try:
                await asyncio.wait_for(fut, remaining)
            except asyncio.TimeoutError:
                return present_fn(key)   # the put may have just raced the
                # timeout: prefer delivering data over a spurious timeout
            finally:
                # timeout AND cancellation (dropped peer/connection) must
                # both unpark, or dead waiter entries pile up forever
                lst = self.waiters.get(key)
                if lst and fut in lst:
                    lst.remove(fut)
                    if not lst:
                        del self.waiters[key]

    def stats(self) -> dict:
        return {"n_waiters": sum(len(v) for v in self.waiters.values())}


class StreamTable:
    """Per-topic sequence numbers + end-of-stream markers + parked
    consumers.  Item *data* rides the owning server's normal key space
    under :func:`stream_item_key` with one reference per item, so consumed
    items decref (and are evicted exactly once) like the ownership
    subsystem's ephemerals.  All mutations happen in synchronous handler
    sections on the server's single event loop.

    **Broker mode**: topics may carry named consumer *groups* — each with
    its own delivery queue, unacked set, and optional metadata filter.  An
    event's payload holds one reference per matching group (evicted after
    the LAST group acks), so the bytes cross the data plane once no matter
    the fanout.  The table only tracks seqs/refcount bookkeeping; payload
    storage and lifetime stay with the owning server (callers translate
    the seq lists this table returns into incref/decref on the derived
    :func:`stream_item_key` keys)."""

    def __init__(self) -> None:
        self.topics: dict[str, dict] = {}     # topic -> {count, closed}
        self._waiters: dict[str, list[asyncio.Future]] = {}
        # broker mode: topic -> group -> {queue, unacked, filter, fn}
        self.groups: dict[str, dict[str, dict]] = {}
        self.owners: dict[str, dict[int, int]] = {}   # seq -> group refs
        self.meta: dict[str, dict[int, dict]] = {}    # seq -> event meta
        self.limits: dict[str, int] = {}              # backpressure bound
        # dead-letter bookkeeping: delivery counts per (group, seq) and the
        # per-topic redelivery bound past which an event is dead-lettered
        self.deliveries: dict[str, dict[tuple[str, int], int]] = {}
        self.max_deliveries: dict[str, int] = {}
        self._gwaiters: dict[tuple[str, str], list[asyncio.Future]] = {}
        self._pwaiters: dict[str, list[asyncio.Future]] = {}

    def state(self, topic: str) -> dict:
        return self.topics.setdefault(topic, {"count": 0, "closed": False})

    def next_seq(self, topic: str) -> int:
        """Sequence number the next append will get; raises when closed."""
        st = self.state(topic)
        if st["closed"]:
            raise RuntimeError(f"stream {topic!r} is closed")
        return st["count"]

    def committed(self, topic: str) -> int:
        """Mark the reserved item as stored and wake parked consumers;
        call AFTER the item's data is in the data map (consumers woken
        before the bytes land would miss on their prefetch mget)."""
        st = self.state(topic)
        seq = st["count"]
        st["count"] += 1
        self._wake(topic)
        return seq

    def close(self, topic: str) -> None:
        self.state(topic)["closed"] = True
        self._wake(topic)
        for group in self.groups.get(topic, ()):
            self._wake_group(topic, group)
        self._wake_producers(topic)   # parked appends fail fast on closed

    def _wake(self, topic: str) -> None:
        for fut in self._waiters.pop(topic, ()):
            if not fut.done():
                fut.set_result(None)

    def _wake_group(self, topic: str, group: str) -> None:
        for fut in self._gwaiters.pop((topic, group), ()):
            if not fut.done():
                fut.set_result(None)

    def _wake_producers(self, topic: str) -> None:
        for fut in self._pwaiters.pop(topic, ()):
            if not fut.done():
                fut.set_result(None)

    # -- broker mode: consumer groups ---------------------------------------
    def subscribe(self, topic: str, group: str, start: str,
                  filter_spec, present_fn) -> tuple[bool, list[int]]:
        """Create consumer group ``group`` (idempotent — an existing group
        is untouched).  ``start="begin"`` queues every retained item that
        passes the group's filter: the FIRST group adopts the item's
        legacy single reference; each later group needs its own, so the
        caller must incref the returned seqs.  Returns
        ``(created, seqs_to_incref)``."""
        groups = self.groups.setdefault(topic, {})
        if group in groups:
            return False, []
        fn = None
        if filter_spec:
            from repro.stream.filters import compile_filter
            fn = compile_filter(filter_spec)
        g = {"queue": collections.deque(), "unacked": set(),
             "filter": filter_spec, "fn": fn}
        groups[group] = g
        increfs: list[int] = []
        if start == "begin":
            owners = self.owners.setdefault(topic, {})
            metas = self.meta.get(topic, {})
            for seq in range(self.state(topic)["count"]):
                if not present_fn(seq):
                    continue          # consumed / reaped / never stored
                if fn is not None and not fn(metas.get(seq) or {}):
                    continue
                g["queue"].append(seq)
                n = owners.get(seq, 0)
                owners[seq] = n + 1
                if n:                 # the legacy ref is already adopted
                    increfs.append(seq)
        return True, increfs

    def unsubscribe(self, topic: str, group: str) -> list[int]:
        """Drop the group; returns the seqs whose group reference the
        caller must release (queued and unacked alike)."""
        g = self.groups.get(topic, {}).pop(group, None)
        if g is None:
            return []
        released = [seq for seq in (*g["queue"], *g["unacked"])
                    if self._drop_owner(topic, seq)]
        d = self.deliveries.get(topic)
        if d:
            for k in [k for k in d if k[0] == group]:
                del d[k]
        if released:
            self._wake_producers(topic)
        return released

    def _drop_owner(self, topic: str, seq: int) -> bool:
        """Release one group reference on ``seq``; True if it was held."""
        owners = self.owners.get(topic)
        n = owners.get(seq) if owners else None
        if n is None:
            return False
        if n <= 1:
            del owners[seq]
            self.meta.get(topic, {}).pop(seq, None)
        else:
            owners[seq] = n - 1
        return True

    def has_groups(self, topic: str) -> bool:
        return bool(self.groups.get(topic))

    def match(self, topic: str, meta: dict | None) -> list[str] | None:
        """Group names whose filter passes ``meta``; None when the topic
        has no groups at all (legacy single-cursor mode)."""
        groups = self.groups.get(topic)
        if not groups:
            return None
        m = meta or {}
        return [name for name, g in groups.items()
                if g["fn"] is None or g["fn"](m)]

    def publish(self, topic: str, seq: int, meta: dict | None,
                matched: list[str]) -> None:
        """Record a stored event: remember its metadata, queue it for each
        matching group, and wake their parked consumers.  Call AFTER the
        payload landed in the data map (a consumer woken early would miss
        on its fetch)."""
        if meta:
            self.meta.setdefault(topic, {})[seq] = dict(meta)
        if matched:
            self.owners.setdefault(topic, {})[seq] = len(matched)
        for name in matched:
            g = self.groups.get(topic, {}).get(name)
            if g is not None:
                g["queue"].append(seq)
                self._wake_group(topic, name)

    def take(self, topic: str, group: str) -> int | None:
        """Pop the group's next deliverable seq (moved to unacked)."""
        g = self.groups.get(topic, {}).get(group)
        if g is None or not g["queue"]:
            return None
        seq = g["queue"].popleft()
        g["unacked"].add(seq)
        d = self.deliveries.setdefault(topic, {})
        d[(group, seq)] = d.get((group, seq), 0) + 1
        return seq

    async def wait_take(self, topic: str, group: str, timeout: float):
        """Park until an event is deliverable to the group; returns its
        seq, the string ``"end"`` (topic closed, nothing left to deliver),
        or None on timeout."""
        loop = asyncio.get_running_loop()
        deadline = loop.time() + float(timeout)
        while True:
            seq = self.take(topic, group)
            if seq is not None:
                return seq
            if self.state(topic)["closed"]:
                return "end"
            remaining = deadline - loop.time()
            if remaining <= 0:
                return None
            fut = loop.create_future()
            self._gwaiters.setdefault((topic, group), []).append(fut)
            try:
                await asyncio.wait_for(fut, remaining)
            except asyncio.TimeoutError:
                seq = self.take(topic, group)
                if seq is not None:
                    return seq
                return "end" if self.state(topic)["closed"] else None
            finally:
                lst = self._gwaiters.get((topic, group))
                if lst and fut in lst:
                    lst.remove(fut)
                    if not lst:
                        del self._gwaiters[(topic, group)]

    def ack(self, topic: str, group: str, seqs) -> list[int]:
        """Per-group ack: returns the seqs that were actually outstanding
        (the caller releases their payload reference).  Seqs the group
        does not hold unacked are ignored — acking twice is harmless."""
        g = self.groups.get(topic, {}).get(group)
        if g is None:
            return []
        done = []
        for seq in seqs:
            seq = int(seq)
            if seq not in g["unacked"]:
                continue
            g["unacked"].discard(seq)
            self._drop_owner(topic, seq)
            self.deliveries.get(topic, {}).pop((group, seq), None)
            done.append(seq)
        if done:
            self._wake_producers(topic)   # acks free backpressure credits
        return done

    def requeue(self, topic: str, group: str, seqs) -> tuple[int, list[int]]:
        """Return delivered-but-unprocessed events to the group's queue
        (merged in sequence order, ahead of later events); returns
        ``(n_requeued, dead_seqs)``.  An event already delivered
        ``max_deliveries`` times is NOT requeued — it lands in
        ``dead_seqs`` and the caller dead-letters it (see
        :meth:`dead_letter`).  No reference changes for requeued events —
        they stay buffered for redelivery."""
        g = self.groups.get(topic, {}).get(group)
        if g is None:
            return 0, []
        back = {int(s) for s in seqs} & g["unacked"]
        if not back:
            return 0, []
        limit = self.max_deliveries.get(topic)
        d = self.deliveries.get(topic, {})
        dead = ([s for s in back if d.get((group, s), 0) >= limit]
                if limit else [])
        back -= set(dead)
        g["unacked"] -= back | set(dead)
        if back:
            g["queue"] = collections.deque(sorted(back | set(g["queue"])))
            self._wake_group(topic, group)
        return len(back), sorted(dead)

    def dead_letter(self, topic: str, group: str, seq: int) -> dict:
        """Drop the group's claim on a poison ``seq``: forget its delivery
        count and release the group's owner reference.  Returns ``{"meta",
        "deliveries", "released"}`` — the caller moves the payload plus
        this metadata to the ``<topic>.dlq`` topic, and decrefs the
        original payload key when ``released`` is True (exactly like an
        ack would)."""
        meta = dict(self.meta.get(topic, {}).get(seq) or {})
        n = self.deliveries.get(topic, {}).pop((group, seq), 0)
        released = self._drop_owner(topic, seq)
        if released:
            self._wake_producers(topic)   # dead-letters free credits too
        return {"meta": meta, "deliveries": n, "released": released}

    def set_limit(self, topic: str, limit, max_deliveries=None) -> None:
        if limit:
            self.limits[topic] = int(limit)
        else:
            self.limits.pop(topic, None)
            self._wake_producers(topic)
        if max_deliveries is not None:
            if max_deliveries:
                self.max_deliveries[topic] = int(max_deliveries)
            else:
                self.max_deliveries.pop(topic, None)

    def buffered(self, topic: str) -> int:
        """Unacked (group-referenced) events buffered on the topic — the
        quantity the backpressure limit bounds."""
        return len(self.owners.get(topic, ()))

    async def wait_capacity(self, topic: str, timeout: float) -> bool:
        """Park the producer until the topic's unacked buffer has room
        (or the topic closes — the append then fails loudly on its own).
        Returns False on timeout."""
        loop = asyncio.get_running_loop()
        deadline = loop.time() + float(timeout)
        while True:
            limit = self.limits.get(topic)
            if (limit is None or self.buffered(topic) < limit
                    or self.state(topic)["closed"]):
                return True
            remaining = deadline - loop.time()
            if remaining <= 0:
                return False
            fut = loop.create_future()
            self._pwaiters.setdefault(topic, []).append(fut)
            try:
                await asyncio.wait_for(fut, remaining)
            except asyncio.TimeoutError:
                limit = self.limits.get(topic)
                return (limit is None or self.buffered(topic) < limit
                        or self.state(topic)["closed"])
            finally:
                lst = self._pwaiters.get(topic)
                if lst and fut in lst:
                    lst.remove(fut)
                    if not lst:
                        del self._pwaiters[topic]

    def describe(self, topic: str) -> dict:
        """``s_stat`` payload: legacy ``{count, closed}`` plus group/
        backpressure state for broker-mode topics."""
        st = dict(self.state(topic))
        groups = self.groups.get(topic)
        if groups:
            st["groups"] = {name: {"queued": len(g["queue"]),
                                   "unacked": len(g["unacked"])}
                            for name, g in groups.items()}
            st["buffered"] = self.buffered(topic)
            if topic in self.limits:
                st["limit"] = self.limits[topic]
            if topic in self.max_deliveries:
                st["max_deliveries"] = self.max_deliveries[topic]
        return st

    # -- replication: cursor snapshot/restore --------------------------------
    def snapshot(self, topic: str) -> dict:
        """One topic's full broker state as a msgpack-safe map — cursor
        (count/closed), group queues + unacked sets + filters, event
        metadata, owner refcounts, limits, and delivery counts.  Payload
        bytes travel separately (chain-forwarded puts of the derived item
        keys)."""
        st = self.state(topic)
        return {
            "count": st["count"], "closed": st["closed"],
            "groups": {name: {"queue": list(g["queue"]),
                              "unacked": sorted(g["unacked"]),
                              "filter": g["filter"]}
                       for name, g in self.groups.get(topic, {}).items()},
            "owners": dict(self.owners.get(topic, {})),
            "meta": dict(self.meta.get(topic, {})),
            "limit": self.limits.get(topic),
            "max_deliveries": self.max_deliveries.get(topic),
            # (group, seq) tuples can't be msgpack map keys: flat triples
            "deliveries": [[g, s, n] for (g, s), n
                           in self.deliveries.get(topic, {}).items()],
        }

    def restore(self, topic: str, snap: dict) -> None:
        """Install a replicated :meth:`snapshot` wholesale (the replica
        side of cursor replication, and the rebalance path that moves a
        topic's home shard).  Parked consumers are woken so they re-check
        the restored state."""
        self.topics[topic] = {"count": int(snap.get("count") or 0),
                              "closed": bool(snap.get("closed"))}
        groups: dict[str, dict] = {}
        for name, g in (snap.get("groups") or {}).items():
            spec = g.get("filter")
            fn = None
            if spec:
                from repro.stream.filters import compile_filter
                fn = compile_filter(spec)
            groups[name] = {
                "queue": collections.deque(int(s) for s in g.get("queue")
                                           or ()),
                "unacked": {int(s) for s in g.get("unacked") or ()},
                "filter": spec, "fn": fn}
        if groups or topic in self.groups:
            self.groups[topic] = groups
        self.owners[topic] = {int(s): int(n)
                              for s, n in (snap.get("owners") or {}).items()}
        self.meta[topic] = {int(s): dict(m)
                            for s, m in (snap.get("meta") or {}).items()}
        self.set_limit(topic, snap.get("limit"),
                       snap.get("max_deliveries") or 0)
        self.deliveries[topic] = {(g, int(s)): int(n)
                                  for g, s, n in snap.get("deliveries") or ()}
        self._wake(topic)
        for name in groups:
            self._wake_group(topic, name)

    def drop(self, topic: str) -> None:
        """Forget the topic entirely (rebalance: the shard no longer homes
        it).  Waiters are woken so parked consumers re-check instead of
        hanging on state that will never advance here."""
        self._wake(topic)
        for name in self.groups.get(topic, {}):
            self._wake_group(topic, name)
        self._wake_producers(topic)
        self.topics.pop(topic, None)
        self.groups.pop(topic, None)
        self.owners.pop(topic, None)
        self.meta.pop(topic, None)
        self.limits.pop(topic, None)
        self.max_deliveries.pop(topic, None)
        self.deliveries.pop(topic, None)

    async def wait_item(self, topic: str, seq: int, timeout: float) -> dict | None:
        """Park until item ``seq`` exists or the stream is closed; returns
        the topic state, or None on timeout."""
        loop = asyncio.get_running_loop()
        deadline = loop.time() + float(timeout)
        while True:
            st = self.state(topic)
            if st["count"] > seq or st["closed"]:
                return st
            remaining = deadline - loop.time()
            if remaining <= 0:
                return None
            fut = loop.create_future()
            self._waiters.setdefault(topic, []).append(fut)
            try:
                await asyncio.wait_for(fut, remaining)
            except asyncio.TimeoutError:
                st = self.state(topic)
                return st if (st["count"] > seq or st["closed"]) else None
            finally:
                # remove on timeout AND cancellation (dropped consumer)
                lst = self._waiters.get(topic)
                if lst and fut in lst:
                    lst.remove(fut)
                    if not lst:
                        del self._waiters[topic]

    def stats(self) -> dict:
        return {"n_topics": len(self.topics),
                "n_stream_waiters": sum(len(v)
                                        for v in self._waiters.values()),
                "n_groups": sum(len(g) for g in self.groups.values()),
                "n_unacked": sum(len(o) for o in self.owners.values())}


def stream_append_locally(streams: StreamTable, lifetime: LifetimeTable,
                          store_fn, topic: str, data, ttl, meta) -> dict:
    """Grouped append, shared by the KV server and the PS-endpoint.

    Topics with subscribed groups store the payload with one reference per
    matching group (evicted after the last ack); an event every group
    filters out is never stored at all.  Topics without groups keep the
    legacy single-reference behavior.  ``store_fn(key, data)`` lands the
    payload in the owning server's data map."""
    seq = streams.next_seq(topic)            # raises when closed
    matched = streams.match(topic, meta)     # None = legacy, [] = filtered
    if matched is None or matched:
        key = stream_item_key(topic, seq)
        store_fn(key, data)
        lifetime.incref(key, 1 if matched is None else len(matched))
        if ttl:
            lifetime.touch(key, ttl)
    streams.publish(topic, seq, meta, matched or [])
    return {"ok": True, "data": streams.committed(topic)}


def stream_group_op(streams: StreamTable, lifetime: LifetimeTable,
                    present_fn, req: dict, dlq_fn=None) -> dict:
    """The synchronous group ops (``s_sub``/``s_unsub``/``s_ack``/
    ``s_requeue``/``s_limit``), shared by the KV server and the
    PS-endpoint.  ``present_fn(key)`` reports data-map membership (used to
    skip already-consumed retained items on a ``start="begin"``
    subscribe).  ``dlq_fn(topic, group, seq, reason)`` dead-letters a
    poison event (moves payload + failure metadata to ``<topic>.dlq`` and
    releases the group's claim); without one, dead events are dropped
    outright — their claim still released so they cannot leak."""
    op, topic = req["op"], req["topic"]
    if op == "s_sub":
        group = req["group"]
        created, increfs = streams.subscribe(
            topic, group, req.get("start", "new"), req.get("filter"),
            lambda seq: present_fn(stream_item_key(topic, seq)))
        for seq in increfs:
            lifetime.incref(stream_item_key(topic, seq))
        st = streams.state(topic)
        g = streams.groups[topic][group]
        return {"ok": True, "data": {"created": created,
                                     "queued": len(g["queue"]),
                                     "count": st["count"],
                                     "closed": st["closed"]}}
    if op == "s_unsub":
        for seq in streams.unsubscribe(topic, req["group"]):
            lifetime.decref(stream_item_key(topic, seq))
        return {"ok": True}
    if op == "s_ack":
        acked = streams.ack(topic, req["group"], req.get("seqs") or ())
        for seq in acked:
            lifetime.decref(stream_item_key(topic, seq))
        return {"ok": True, "data": len(acked)}
    if op == "s_requeue":
        group = req["group"]
        n, dead = streams.requeue(topic, group, req.get("seqs") or ())
        for seq in dead:
            if dlq_fn is not None:
                dlq_fn(topic, group, seq, req.get("reason"))
            else:
                info = streams.dead_letter(topic, group, seq)
                if info["released"]:
                    lifetime.decref(stream_item_key(topic, seq))
        return {"ok": True, "data": n, "dead": dead}
    if op == "s_limit":
        streams.set_limit(topic, req.get("limit"),
                          req.get("max_deliveries"))
        return {"ok": True}
    return {"ok": False, "error": f"unknown stream op {op!r}"}


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------
class KVServer:
    SWEEP_INTERVAL = LifetimeTable.SWEEP_INTERVAL

    def __init__(self, persist_dir: str | None = None,
                 peer_timeout: float | None = None) -> None:
        # values are bytes-like: put2/s_append land the received bytearray
        # itself, mput2 lands sliced views of the one batch buffer
        self._data: dict[str, Any] = {}
        self.lifetime = LifetimeTable(self._evict)
        self.waiters = WaiterTable()
        self.streams = StreamTable()
        self._persist = Path(persist_dir) if persist_dir else None
        self._n_ops = 0
        # payload-serve accounting: every op that ships stored payload
        # bytes to a client bumps these (the fanout benchmark's served-
        # bytes ratio, and the proof that filtered-out events do ZERO
        # payload-path work, both read them from ``stats``)
        self._n_payload_serves = 0
        self._payload_bytes = 0
        # shard-to-shard plane: lazily-dialed peer clients for chain
        # replication forwards, hinted-handoff replays, and cursor pushes.
        # Peer calls run on the loop's default executor (the loop itself
        # never blocks on a peer socket); the hop timeout is deliberately
        # shorter than client timeouts so a dead successor fails the hop —
        # reported in the put response — instead of stalling the put.
        if peer_timeout is None:
            peer_timeout = float(os.environ.get("REPRO_PEER_TIMEOUT", "5.0"))
        self.peer_timeout = peer_timeout
        self._peers: dict[str, KVClient] = {}
        self._peers_lock = threading.Lock()
        self._hints: dict[str, list[str]] = {}    # owner addr -> hinted keys
        self._stream_chain: dict[str, list[str]] = {}
        self._push_dirty: set[str] = set()
        self._n_chain_forwards = 0
        self._n_chain_errors = 0
        self._n_hint_stores = 0
        self._n_hint_replays = 0
        self._n_cursor_pushes = 0
        self._n_cursor_push_errors = 0
        self._n_dead_letters = 0
        self._io_pool: ThreadPoolExecutor | None = None
        if self._persist:
            self._persist.mkdir(parents=True, exist_ok=True)
            for f in self._persist.glob("*.kv"):
                self._data[f.stem] = f.read_bytes()
            # disk writes happen here, never on the event loop: one
            # persisting client must not stall every connected client
            self._io_pool = ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="kv-persist")
        self._shutdown = asyncio.Event()

    # -- op handlers --------------------------------------------------------
    def _store_mem(self, key: str, data: bytes) -> None:
        """EVERY memory write funnels through here so parked ``wait``-ers
        are released no matter which put variant landed the key."""
        self._data[key] = data
        self.waiters.wake(key)

    def _put(self, key: str, data: bytes) -> None:
        """Synchronous put (memory + write-through disk); used by the legacy
        in-band path and by tests driving ``handle`` directly."""
        self._store_mem(key, data)
        if self._persist:
            self._persist_write(key, data)

    def _persist_write(self, key: str, data: bytes) -> None:
        tmp = self._persist / f".{key}.tmp"
        tmp.write_bytes(data)
        tmp.replace(self._persist / f"{key}.kv")

    async def _put_async(self, key: str, data: bytes) -> None:
        """Memory write now (so later requests on any connection see it),
        disk write-through on the executor (so the loop never blocks);
        responds only once the write is durable."""
        self._store_mem(key, data)
        if self._persist:
            loop = asyncio.get_running_loop()
            await loop.run_in_executor(self._io_pool, self._persist_write,
                                       key, data)

    def _evict(self, key: str) -> None:
        self._data.pop(key, None)
        self.lifetime.drop(key)
        if self._persist:
            (self._persist / f"{key}.kv").unlink(missing_ok=True)

    def _touch(self, key: str, ttl) -> bool:
        self.lifetime.touch(key, ttl)
        return key in self._data

    def _maybe_sweep(self) -> None:
        self.lifetime.maybe_sweep()

    def _count_serve(self, data) -> None:
        self._n_payload_serves += 1
        self._payload_bytes += len(data)

    # -- shard-to-shard plane: chain replication, hints, cursor pushes ------
    def _peer(self, addr: str) -> KVClient:
        """Lazily-dialed client to a peer shard (``host:port`` or
        ``unix:/path``).  Called from executor threads — the dict is
        lock-guarded and the blocking connect happens off the loop."""
        with self._peers_lock:
            c = self._peers.get(addr)
            if c is None:
                if is_uds(addr):
                    host, port = addr, 0
                else:
                    host, _, port_s = addr.rpartition(":")
                    host, port = host or addr, int(port_s or 0)
                c = KVClient(host, port, timeout=self.peer_timeout)
                self._peers[addr] = c
        return c

    async def _chain_forward(self, items, chain) -> list[str]:
        """Chain replication: forward stored puts to each ring successor in
        ``chain`` over a shard-to-shard connection — one ``mput2`` per
        successor (plain, no ``chain`` field: a forward never re-forwards)
        — awaiting every hop's ack.  Returns the addrs that failed; the
        caller reports them so the client can queue repairs."""
        loop = asyncio.get_running_loop()
        keys = [k for k, _ in items]
        blobs = [b for _, b in items]

        def _fwd(addr: str) -> None:
            self._peer(addr).mput(keys, blobs)

        futs = [(addr, loop.run_in_executor(None, _fwd, addr))
                for addr in chain]
        errs: list[str] = []
        for addr, f in futs:
            try:
                await f
                self._n_chain_forwards += 1
            except Exception:  # noqa: BLE001 - a dead hop fails, not the put
                self._n_chain_errors += 1
                errs.append(addr)
        return errs

    def _apply_put_state(self, req: dict, key: str | None = None) -> None:
        """Install the lifecycle/hint state riding on a ``put2``: an
        initial refcount (``refs``), a lease (``ttl``), and/or a hinted-
        handoff record (``hint_for`` — the suspect owner this shard is
        holding the key for)."""
        key = key if key is not None else req["key"]
        n = int(req.get("refs") or 0)
        if n > 0:
            self.lifetime.incref(key, n)
        ttl = req.get("ttl")
        if ttl:
            self.lifetime.touch(key, ttl)
        owner = req.get("hint_for")
        if owner:
            self._hints.setdefault(owner, []).append(key)
            self._n_hint_stores += 1

    def _hint_replay_plan(self, owner: str) -> list[tuple]:
        """Snapshot the hinted keys owed to ``owner`` — (key, bytes,
        refcount, remaining-lease) tuples — synchronously on the loop, so
        the executor thread that replays them touches no shared state."""
        keys = self._hints.pop(owner, [])
        now = time.monotonic()
        plan = []
        for key in dict.fromkeys(keys):       # dedup, keep order
            data = self._data.get(key)
            if data is None:
                continue                      # consumed/reaped: nothing owed
            lease = self.lifetime.leases.get(key)
            plan.append((key, data, self.lifetime.refs.get(key, 0),
                         round(lease - now, 3) if lease and lease > now
                         else None))
        return plan

    def _dead_letter(self, topic: str, group: str, seq: int,
                     reason: str | None = None) -> None:
        """Move a poison event to ``<topic>.dlq``: append the payload (if
        still present) under the DLQ topic with the original metadata plus
        a ``"dlq"`` failure record, then release the group's claim on the
        original — exactly the reference an ack would drop."""
        key = stream_item_key(topic, seq)
        info = self.streams.dead_letter(topic, group, seq)
        data = self._data.get(key)
        meta = info["meta"]
        meta["dlq"] = {"topic": topic, "group": group, "seq": seq,
                       "deliveries": info["deliveries"],
                       "reason": reason or "max_deliveries"}
        stream_append_locally(self.streams, self.lifetime, self._store_mem,
                              dlq_topic(topic),
                              data if data is not None else b"", None, meta)
        self._n_dead_letters += 1
        if info["released"]:
            self.lifetime.decref(key)

    def _schedule_push(self, topic: str) -> None:
        """Coalesced asynchronous cursor push: after a group-state
        mutation, ship the topic's snapshot to its replica chain.  A crash
        before the push lands costs duplicate deliveries after failover
        (at-least-once), never skipped events — committed appends push
        synchronously in the ``s_append`` handler instead."""
        if not self._stream_chain.get(topic):
            return
        try:
            loop = asyncio.get_running_loop()
        except RuntimeError:
            return     # driven directly (tests): no loop, no replication
        if topic in self._push_dirty:
            return     # a scheduled push will snapshot the latest state
        self._push_dirty.add(topic)
        loop.create_task(self._push_stream_state(topic))

    async def _push_stream_state(self, topic: str,
                                 chain: list[str] | None = None) -> list[str]:
        """Push the topic's current snapshot to every chain member;
        returns the addrs that failed."""
        self._push_dirty.discard(topic)
        chain = chain if chain is not None else self._stream_chain.get(topic)
        if not chain:
            return []
        snap = self.streams.snapshot(topic)
        loop = asyncio.get_running_loop()

        def _push(addr: str) -> None:
            resp = self._peer(addr).request(
                {"op": "s_restore", "topic": topic, "state": snap})
            if not resp.get("ok"):
                raise RuntimeError(resp.get("error"))

        futs = [(addr, loop.run_in_executor(None, _push, addr))
                for addr in chain]
        errs: list[str] = []
        for addr, f in futs:
            try:
                await f
                self._n_cursor_pushes += 1
            except Exception:  # noqa: BLE001 - dead replica, not fatal here
                self._n_cursor_push_errors += 1
                errs.append(addr)
        return errs

    def handle(self, req: dict) -> dict:
        self._n_ops += 1
        self._maybe_sweep()
        op = req["op"]
        if op == "put":
            self._put(req["key"], req["data"])
            return {"ok": True}
        if op == "get":
            data = self._data.get(req["key"])
            if data is not None:
                self._count_serve(data)
            return {"ok": True, "data": data}
        if op == "exists":
            return {"ok": True, "data": req["key"] in self._data}
        if op == "evict":
            self._evict(req["key"])
            return {"ok": True}
        if op == "mput":
            for k, b in zip(req["keys"], req["blobs"]):
                self._put(k, b)
            return {"ok": True}
        if op == "mget":
            datas = [self._data.get(k) for k in req["keys"]]
            for d in datas:
                if d is not None:
                    self._count_serve(d)
            return {"ok": True, "data": datas}
        if op in ("s_sub", "s_unsub", "s_ack", "s_requeue", "s_limit"):
            chain = req.get("chain")
            if chain is not None:
                # replica chain riding a group op (the fabric installs it
                # on first contact): absolute set, empty clears
                topic = req["topic"]
                if chain:
                    self._stream_chain[topic] = [str(a) for a in chain]
                else:
                    self._stream_chain.pop(topic, None)
            resp = stream_group_op(self.streams, self.lifetime,
                                   self._data.__contains__, req,
                                   dlq_fn=self._dead_letter)
            self._schedule_push(req["topic"])
            return resp
        if op == "s_chain":
            topic, chain = req["topic"], req.get("chain") or []
            if chain:
                self._stream_chain[topic] = [str(a) for a in chain]
            else:
                self._stream_chain.pop(topic, None)
            self._schedule_push(topic)   # seed the replicas right away
            return {"ok": True}
        if op == "s_snap":
            return {"ok": True, "data": self.streams.snapshot(req["topic"])}
        if op == "s_restore":
            topic = req["topic"]
            self.streams.restore(topic, req.get("state") or {})
            # reconcile payload-key refcounts with the replicated owner
            # counts (evict-after-last-ack must keep working after a
            # failover promotes this replica), and prune payloads no group
            # retains any more
            owned = {}
            for seq, n in self.streams.owners.get(topic, {}).items():
                key = stream_item_key(topic, seq)
                if key in self._data:
                    owned[key] = int(n)
            prefix = f"@s:{topic}:"
            for key in [k for k in self._data if k.startswith(prefix)
                        and k not in owned]:
                self._evict(key)
            for key, n in owned.items():
                self.lifetime.refs[key] = n
            return {"ok": True}
        if op == "s_drop":
            topic = req["topic"]
            prefix = f"@s:{topic}:"
            for key in [k for k in self._data if k.startswith(prefix)]:
                self._evict(key)
            self.streams.drop(topic)
            self._stream_chain.pop(topic, None)
            return {"ok": True}
        if op == "hints":
            return {"ok": True,
                    "data": {owner: list(keys)
                             for owner, keys in self._hints.items()}}
        if op == "mevict":
            for k in req["keys"]:
                self._evict(k)
            return {"ok": True}
        if op == "mexists":
            return {"ok": True, "data": [k in self._data for k in req["keys"]]}
        if op == "incref":
            return {"ok": True, "data": self.lifetime.incref(req["key"],
                                                             req.get("n", 1))}
        if op == "decref":
            return {"ok": True, "data": self.lifetime.decref(req["key"],
                                                             req.get("n", 1))}
        if op == "mincref":
            n = req.get("n", 1)
            return {"ok": True,
                    "data": [self.lifetime.incref(k, n) for k in req["keys"]]}
        if op == "mdecref":
            n = req.get("n", 1)
            return {"ok": True,
                    "data": [self.lifetime.decref(k, n) for k in req["keys"]]}
        if op == "refcount":
            return {"ok": True, "data": self.lifetime.refs.get(req["key"], 0)}
        if op == "refsnap":
            # sanitizer close-time cross-check: the whole refcount table
            return {"ok": True, "data": self.lifetime.snapshot()}
        if op == "touch":
            return {"ok": True, "data": self._touch(req["key"], req.get("ttl"))}
        if op == "mtouch":
            ttl = req.get("ttl")
            return {"ok": True,
                    "data": [self._touch(k, ttl) for k in req["keys"]]}
        if op == "keyspace":
            # rebalance support: every plain key plus its lifecycle state
            # (refcount, lease seconds REMAINING — relative, so the
            # receiving shard re-anchors on its own monotonic clock).
            # Stream item keys are excluded: topics don't migrate.
            now = time.monotonic()
            keys = [k for k in self._data if not k.startswith("@s:")]
            present = set(keys)
            return {"ok": True, "data": {
                "keys": keys,
                "refs": {k: n for k, n in self.lifetime.refs.items()
                         if k in present},
                "leases": {k: round(t - now, 3)
                           for k, t in self.lifetime.leases.items()
                           if k in present and t > now},
            }}
        if op == "ping":
            return {"ok": True, "data": "pong"}
        if op == "stats":
            return {"ok": True, "data": {
                "n_objects": len(self._data),
                "bytes": sum(len(v) for v in self._data.values()),
                "n_ops": self._n_ops,
                "n_payload_serves": self._n_payload_serves,
                "payload_bytes_served": self._payload_bytes,
                "n_chain_forwards": self._n_chain_forwards,
                "n_chain_errors": self._n_chain_errors,
                "n_hints_pending": sum(len(v) for v in self._hints.values()),
                "n_hint_stores": self._n_hint_stores,
                "n_hint_replays": self._n_hint_replays,
                "n_cursor_pushes": self._n_cursor_pushes,
                "n_cursor_push_errors": self._n_cursor_push_errors,
                "n_dead_letters": self._n_dead_letters,
                **self.lifetime.stats(),
                **self.waiters.stats(),
                **self.streams.stats(),
            }}
        if op == "shutdown":
            self._shutdown.set()
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    # -- connection handling ------------------------------------------------
    @staticmethod
    async def _send(writer: asyncio.StreamWriter, lock: asyncio.Lock,
                    resp: dict, raw: tuple | None = None) -> None:
        """Write a response frame (+ optional raw payloads) atomically with
        respect to other in-flight responses on this connection."""
        body = msgpack.packb(resp, use_bin_type=True)
        async with lock:
            writer.write(_LEN.pack(len(body)) + body)
            if raw:
                for blob in raw:
                    writer.write(blob)
            await writer.drain()

    # ops with await points (parked, timed, or executor-bound) — these can
    # never take the inline fast path
    _ASYNC_OPS = frozenset({"wait", "mwait", "s_next", "s_next2", "sleep",
                            "shutdown", "hint_replay"})

    def try_sync(self, req: dict, payload) -> tuple[dict, tuple | None] | None:
        """Handle a request with NO await points synchronously; returns
        ``(resp, raw_payloads)`` or None when the op must run on a task
        (parked/slow ops, persistence write-through).  This is the inline
        fast path: the protocol answers these straight from the read
        callback — no task spawn, no drain round."""
        op = req.get("op")
        if op in self._ASYNC_OPS:
            return None
        if op == "s_append" and req.get("topic") in self.streams.limits:
            return None          # backpressure: the append may park
        if op == "s_append" and req.get("topic") in self._stream_chain:
            return None          # chained: forwards await peer acks
        if req.get("chain") and op in ("put2", "mput2", "s_append"):
            return None          # chain forwarding awaits peer acks
        if self._persist and op in ("put", "mput", "put2", "mput2"):
            return None          # disk write-through rides the executor
        self._maybe_sweep()
        raw: tuple | None = None
        try:
            if op == "put2":
                self._n_ops += 1
                self._store_mem(req["key"], payload)
                self._apply_put_state(req)
                resp = {"ok": True}
            elif op == "mput2":
                self._n_ops += 1
                # sliced views, not bytes() copies: each key's value aliases
                # its span of the one received batch buffer.  The batch
                # buffer stays pinned while ANY of its keys is live — the
                # price of a zero-copy ingest, bounded by the batch size.
                mv = memoryview(payload)
                off = 0
                for k, n in zip(req["keys"], req["nbytes"]):
                    self._store_mem(k, mv[off:off + n])
                    self._apply_put_state(req, key=k)
                    off += n
                resp = {"ok": True}
            elif op == "get2":
                self._n_ops += 1
                data = self._data.get(req["key"])
                resp = {"ok": True, "raw": -1 if data is None else len(data)}
                if data is not None:
                    raw = (data,)
                    self._count_serve(data)
            elif op == "mget2":
                self._n_ops += 1
                datas = [self._data.get(k) for k in req["keys"]]
                resp = {"ok": True,
                        "raws": [-1 if d is None else len(d) for d in datas]}
                raw = tuple(d for d in datas if d is not None)
                for d in raw:
                    self._count_serve(d)
            elif op == "s_append":
                # data first, count bump + consumer wake second: a consumer
                # woken before the bytes land would miss on its prefetch.
                # (Stream items are ephemerals — never persisted.)
                self._n_ops += 1
                resp = stream_append_locally(
                    self.streams, self.lifetime, self._store_mem,
                    req["topic"], payload, req.get("ttl"), req.get("meta"))
            elif op == "s_fetch":
                # non-blocking batch take for one consumer group: seqs +
                # metas in-band, payload blobs mget2-style out-of-band
                # (delivered events move to the group's unacked set; the
                # ack releases their references separately)
                self._n_ops += 1
                topic, group = req["topic"], req["group"]
                want = req.get("payload", True)
                seqs: list[int] = []
                while len(seqs) < int(req.get("n", 1)):
                    seq = self.streams.take(topic, group)
                    if seq is None:
                        break
                    seqs.append(seq)
                metas = self.streams.meta.get(topic, {})
                st = self.streams.state(topic)
                resp = {"ok": True, "seqs": seqs,
                        "metas": [metas.get(s) or {} for s in seqs],
                        "available": st["count"], "closed": st["closed"]}
                if want:
                    datas = [self._data.get(stream_item_key(topic, s))
                             for s in seqs]
                    resp["raws"] = [-1 if d is None else len(d)
                                    for d in datas]
                    raw = tuple(d for d in datas if d is not None)
                    for d in raw:
                        self._count_serve(d)
                if seqs:
                    self._schedule_push(topic)   # cursor moved: replicate
            elif op == "s_close":
                self._n_ops += 1
                self.streams.close(req["topic"])
                self._schedule_push(req["topic"])
                resp = {"ok": True}
            elif op == "s_stat":
                self._n_ops += 1
                resp = {"ok": True,
                        "data": self.streams.describe(req["topic"])}
            else:
                resp = self.handle(req)
        except Exception as e:  # noqa: BLE001 - surface to client
            resp, raw = {"ok": False, "error": str(e)}, None
        return resp, raw

    async def _handle_one(self, req: dict, payload, writer, lock) -> None:
        op = req.get("op")
        seq = req.get("seq")
        raw: tuple | None = None
        sync = self.try_sync(req, payload)
        if sync is not None:
            # an op with no await points, running on a task anyway (an
            # earlier async op on this connection is still in flight, so
            # the inline path was skipped to preserve submission order)
            resp, raw = sync
            if seq is not None:
                resp["seq"] = seq
            try:
                await self._send(writer, lock, resp, raw)
            except (ConnectionError, OSError):
                pass
            return
        self._maybe_sweep()
        try:
            if op == "put2":
                self._n_ops += 1
                await self._put_async(req["key"], payload)
                self._apply_put_state(req)
                resp = {"ok": True}
                chain = req.get("chain")
                if chain:
                    errs = await self._chain_forward(
                        [(req["key"], payload)], chain)
                    resp["chain_acks"] = len(chain) - len(errs)
                    if errs:
                        resp["chain_errors"] = errs
            elif op == "mput2":
                self._n_ops += 1
                mv = memoryview(payload)
                off = 0
                stores = []
                for k, n in zip(req["keys"], req["nbytes"]):
                    blob = mv[off:off + n]
                    off += n
                    self._store_mem(k, blob)
                    self._apply_put_state(req, key=k)
                    stores.append((k, blob))
                if self._persist:
                    loop = asyncio.get_running_loop()

                    def _persist_all(items=stores):
                        for k, b in items:
                            self._persist_write(k, b)

                    await loop.run_in_executor(self._io_pool, _persist_all)
                resp = {"ok": True}
                chain = req.get("chain")
                if chain:
                    errs = await self._chain_forward(stores, chain)
                    resp["chain_acks"] = len(chain) - len(errs)
                    if errs:
                        resp["chain_errors"] = errs
            elif op == "wait":
                # a get2 that parks until the put lands; completes out of
                # order behind faster ops, like sleep does
                self._n_ops += 1
                data = await self.waiters.wait_for(
                    req["key"], self._data.get,
                    float(req.get("timeout", 60.0)))
                if data is None:
                    resp = {"ok": False, "timeout": True,
                            "error": f"wait timed out on {req['key']!r}"}
                else:
                    resp = {"ok": True, "raw": len(data)}
                    raw = (data,)
                    self._count_serve(data)
            elif op == "mwait":
                self._n_ops += 1
                loop = asyncio.get_running_loop()
                deadline = loop.time() + float(req.get("timeout", 60.0))
                datas = [await self.waiters.wait_for(
                    k, self._data.get, 0.0, deadline=deadline)
                    for k in req["keys"]]
                resp = {"ok": True,
                        "raws": [-1 if d is None else len(d) for d in datas]}
                if any(d is None for d in datas):
                    resp["timeout"] = True
                raw = tuple(d for d in datas if d is not None)
                for d in raw:
                    self._count_serve(d)
            elif op == "s_next":
                self._n_ops += 1
                # stream position rides as "i": "seq" is the connection's
                # multiplexing tag (and the local holding it, echoed below)
                topic, pos = req["topic"], int(req["i"])
                st = await self.streams.wait_item(
                    topic, pos, float(req.get("timeout", 60.0)))
                if st is None:
                    resp = {"ok": False, "timeout": True,
                            "error": f"stream {topic!r} item {pos} "
                                     f"timed out"}
                elif st["count"] > pos:
                    key = stream_item_key(topic, pos)
                    data = self._data.get(key)
                    resp = {"ok": True,
                            "raw": -1 if data is None else len(data),
                            "available": st["count"],
                            "closed": st["closed"]}
                    if data is None:     # already consumed by another reader
                        resp["missing"] = True
                    else:
                        raw = (data,)
                        self._count_serve(data)
                        if req.get("consume", True):
                            self.lifetime.decref(key)
                else:                    # closed before this item: end marker
                    resp = {"ok": True, "raw": -1, "end": True,
                            "available": st["count"], "closed": True}
            elif op == "s_next2":
                # blocking group take: parks until an event is deliverable
                # to THIS group (or the topic closes).  Delivery does not
                # release the payload reference — the group acks when done.
                self._n_ops += 1
                topic, group = req["topic"], req["group"]
                got = await self.streams.wait_take(
                    topic, group, float(req.get("timeout", 60.0)))
                if got is None:
                    resp = {"ok": False, "timeout": True,
                            "error": f"stream {topic!r} group {group!r} "
                                     f"timed out"}
                elif got == "end":
                    st = self.streams.state(topic)
                    resp = {"ok": True, "raw": -1, "end": True,
                            "available": st["count"], "closed": True}
                else:
                    st = self.streams.state(topic)
                    resp = {"ok": True, "i": got,
                            "meta": self.streams.meta.get(topic, {})
                                                     .get(got) or {},
                            "available": st["count"],
                            "closed": st["closed"]}
                    if req.get("payload", True):
                        data = self._data.get(stream_item_key(topic, got))
                        resp["raw"] = -1 if data is None else len(data)
                        if data is None:   # lease-reaped under the group
                            resp["missing"] = True
                        else:
                            raw = (data,)
                            self._count_serve(data)
                    else:                  # metadata-only tap: the payload
                        resp["raw"] = -1   # bytes are never served
                    self._schedule_push(topic)   # cursor moved: replicate
            elif op == "s_append":
                # lands here for topics with a backpressure limit (the
                # append may park) or a replica chain (the forward awaits
                # peer acks) — try_sync refuses both
                self._n_ops += 1
                topic = req["topic"]
                if await self.streams.wait_capacity(
                        topic, float(req.get("timeout", 60.0))):
                    resp = stream_append_locally(
                        self.streams, self.lifetime, self._store_mem,
                        topic, payload, req.get("ttl"), req.get("meta"))
                    chain = req.get("chain")
                    if chain is not None:    # riding the append: install
                        chain = [str(a) for a in chain]
                        if chain:
                            self._stream_chain[topic] = chain
                        else:
                            self._stream_chain.pop(topic, None)
                    else:
                        chain = self._stream_chain.get(topic)
                    if resp.get("ok") and chain:
                        # a committed append is durable: payload + cursor
                        # snapshot reach every chain member BEFORE the ack,
                        # so a failover replica re-delivers, never skips
                        key = stream_item_key(topic, int(resp["data"]))
                        data = self._data.get(key)
                        errs: set[str] = set()
                        if data is not None:
                            errs.update(await self._chain_forward(
                                [(key, data)], chain))
                        errs.update(await self._push_stream_state(
                            topic, chain=chain))
                        resp["chain_acks"] = len(chain) - len(errs)
                        if errs:
                            resp["chain_errors"] = sorted(errs)
                else:
                    resp = {"ok": False, "timeout": True,
                            "error": f"stream {topic!r} append timed out "
                                     f"on backpressure (buffer full)"}
            elif op == "hint_replay":
                # hinted handoff, replay side: re-put every key this shard
                # held for the (recovered) owner — bytes + refcount +
                # remaining lease — over the shard-to-shard connection
                self._n_ops += 1
                owner = req["owner"]
                plan = self._hint_replay_plan(owner)
                loop = asyncio.get_running_loop()

                def _replay() -> int:
                    peer = self._peer(owner)
                    for key, data, refs, ttl in plan:
                        msg, segs = (
                            {"op": "put2", "key": key,
                             "nbytes": len(data)}, [data])
                        if refs:
                            msg["refs"] = refs
                        if ttl:
                            msg["ttl"] = ttl
                        r = peer.request(msg, payload=segs, retry=False)
                        if not r.get("ok"):
                            raise RuntimeError(r.get("error"))
                    return len(plan)

                try:
                    sent = await loop.run_in_executor(None, _replay)
                    self._n_hint_replays += sent
                    resp = {"ok": True, "data": {"replayed": sent}}
                except Exception as e:  # noqa: BLE001 - keep hints, report
                    self._hints.setdefault(owner, []).extend(
                        key for key, _, _, _ in plan)
                    resp = {"ok": False,
                            "error": f"hint replay to {owner!r} failed: {e}"}
            elif op == "sleep":
                await asyncio.sleep(float(req.get("s", 0.0)))
                self._n_ops += 1
                resp = {"ok": True}
            elif op in ("put", "mput") and self._persist:
                # legacy in-band puts also keep disk I/O off the loop
                items = ([(req["key"], req["data"])] if op == "put"
                         else list(zip(req["keys"], req["blobs"])))
                self._n_ops += 1
                for k, b in items:
                    self._store_mem(k, b)
                loop = asyncio.get_running_loop()

                def _persist_all(its=items):
                    for k, b in its:
                        self._persist_write(k, b)

                await loop.run_in_executor(self._io_pool, _persist_all)
                resp = {"ok": True}
            else:
                resp = self.handle(req)
        except Exception as e:  # noqa: BLE001 - surface to client
            resp = {"ok": False, "error": str(e)}
            raw = None
        if seq is not None:
            resp["seq"] = seq
        try:
            await self._send(writer, lock, resp, raw)
        except (ConnectionError, OSError):
            pass

class _TransportWriter:
    """StreamWriter-shaped shim over a raw transport (``write``/``drain``/
    ``close``) for :class:`KVIngestProtocol`, with drain back-pressure
    driven by the protocol's pause/resume callbacks."""

    __slots__ = ("_transport", "_paused", "_waiters", "_exc")

    def __init__(self, transport: asyncio.Transport) -> None:
        self._transport = transport
        self._paused = False
        self._waiters: list[asyncio.Future] = []
        self._exc: BaseException | None = None

    def write(self, data) -> None:
        self._transport.write(data)

    def close(self) -> None:
        self._transport.close()

    async def drain(self) -> None:
        if self._exc is not None:
            raise ConnectionResetError("connection lost") from self._exc
        if self._transport.is_closing():
            raise ConnectionResetError("connection closing")
        if self._paused:
            fut = asyncio.get_running_loop().create_future()
            self._waiters.append(fut)
            await fut

    def _pause(self) -> None:
        self._paused = True

    def _resume(self) -> None:
        self._paused = False
        for fut in self._waiters:
            if not fut.done():
                fut.set_result(None)
        self._waiters.clear()

    def _connection_lost(self, exc: BaseException | None) -> None:
        self._exc = exc or ConnectionResetError("connection lost")
        for fut in self._waiters:
            if not fut.done():
                fut.set_exception(ConnectionResetError("connection lost"))
        self._waiters.clear()


class KVIngestProtocol(asyncio.BufferedProtocol):
    """Copy-free server ingest (one connection).

    A buffered protocol so the transport ``recv_into``s directly into OUR
    buffers: small frame traffic lands in a reusable scratch buffer, and an
    announced out-of-band payload (``put2``/``mput2``/``s_append``) is
    received straight into its **final** bytearray — the exact object the
    data map will reference — so the whole ingest path is one kernel→user
    copy with no StreamReader staging buffer and no ``bytes()`` re-copy.

    Requests dispatch onto tasks exactly like the old reader loop did:
    submission order is preserved for their synchronous prefixes, slow ops
    (persist, sleep, parked waits) complete out of order behind fast ones.
    """

    _SCRATCH = 256 * 1024

    def __init__(self, kv: KVServer) -> None:
        self.kv = kv
        self._scratch = bytearray(self._SCRATCH)
        self._rpos = 0               # parse cursor into scratch
        self._wpos = 0               # received-bytes high-water in scratch
        self._frame_len: int | None = None
        self._payload: bytearray | None = None   # in-flight OOB target
        self._payload_fill = 0
        self._payload_req: dict | None = None
        self._writer: _TransportWriter | None = None
        self._lock = asyncio.Lock()
        self._tasks: set[asyncio.Task] = set()
        self._dead = False           # unrecoverable stream: stop parsing

    # -- transport callbacks -------------------------------------------------
    def connection_made(self, transport) -> None:
        sock = transport.get_extra_info("socket")
        if sock is not None:
            try:
                # responses are header-then-payload write pairs: Nagle
                # holding the second half for the client's ACK would add a
                # delayed-ACK round to every get
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass   # unix sockets have no Nagle — NOT a reason to skip
                # the buffer sizing below (AF_UNIX defaults to ~208 KB,
                # which costs a context-switch ping-pong per 1 MB payload)
            try:
                # MB-scale payloads: bigger kernel buffers mean fewer
                # epoll_wait/recv_into rounds per transfer
                sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, _SOCKBUF)
                sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, _SOCKBUF)
            except OSError:  # pragma: no cover
                pass
        self._writer = _TransportWriter(transport)

    def connection_lost(self, exc) -> None:
        if self._writer is not None:
            self._writer._connection_lost(exc)

    def eof_received(self) -> bool:
        return False                 # close the transport

    def pause_writing(self) -> None:
        # the peer is slow draining responses: stop reading too, so the
        # inline fast path (which writes without awaiting drain) cannot
        # grow the transport buffer unboundedly
        self._writer._pause()
        try:
            self._writer._transport.pause_reading()
        except (RuntimeError, AttributeError):  # pragma: no cover
            pass

    def resume_writing(self) -> None:
        self._writer._resume()
        try:
            self._writer._transport.resume_reading()
        except (RuntimeError, AttributeError):  # pragma: no cover
            pass

    def get_buffer(self, sizehint: int) -> memoryview:
        if self._payload is not None:
            # recv_into the payload's FINAL buffer — no staging copy
            return memoryview(self._payload)[self._payload_fill:]
        if self._wpos == len(self._scratch):
            self._make_room(1)
        return memoryview(self._scratch)[self._wpos:]

    def buffer_updated(self, nbytes: int) -> None:
        if self._dead:
            # stream is beyond resync (e.g. an unconsumed payload follows
            # a rejected announcement): drop everything until the close
            # lands — the bytes must NOT be parsed as frames
            return
        if self._payload is not None:
            self._payload_fill += nbytes
            if self._payload_fill >= len(self._payload):
                req, payload = self._payload_req, self._payload
                self._payload = self._payload_req = None
                self._dispatch(req, payload)
            return
        self._wpos += nbytes
        self._parse()

    # -- scratch management --------------------------------------------------
    def _make_room(self, need: int) -> None:
        """Guarantee ``need`` contiguous writable bytes after ``_wpos``.
        Never resizes in place (the transport may still hold an exported
        view of the old buffer): compaction slides within it, growth swaps
        in a fresh bytearray."""
        live = self._wpos - self._rpos
        if self._rpos and len(self._scratch) - live >= need:
            self._scratch[:live] = self._scratch[self._rpos:self._wpos]
            self._rpos, self._wpos = 0, live
        if len(self._scratch) - self._wpos < need:
            new = bytearray(max(len(self._scratch) * 2,
                                self._wpos - self._rpos + need))
            new[:self._wpos - self._rpos] = \
                self._scratch[self._rpos:self._wpos]
            self._wpos -= self._rpos
            self._rpos = 0
            self._scratch = new

    # -- frame parsing -------------------------------------------------------
    def _parse(self) -> None:
        while True:
            avail = self._wpos - self._rpos
            if self._frame_len is None:
                if avail < 4:
                    break
                (length,) = _LEN.unpack_from(self._scratch, self._rpos)
                if length > MAX_FRAME:
                    self._dead = True
                    self._writer.close()
                    return
                self._rpos += 4
                self._frame_len = length
                continue
            if avail < self._frame_len:
                self._make_room(self._frame_len - avail)
                break
            body = memoryview(self._scratch)[
                self._rpos:self._rpos + self._frame_len]
            try:
                req = msgpack.unpackb(body, raw=False, strict_map_key=False)
            finally:
                body.release()       # scratch must stay swappable
            self._rpos += self._frame_len
            self._frame_len = None
            op = req.get("op")
            if op in ("put2", "mput2", "s_append"):
                sizes = ([int(req["nbytes"])] if op != "mput2"
                         else [int(n) for n in req["nbytes"]])
                total = sum(sizes)
                if total > MAX_FRAME or any(n < 0 for n in sizes):
                    # can't resync the stream without consuming the
                    # payload; report the reason, then drop the conn
                    self._reject(req, f"payload too large: {total}")
                    return
                payload = bytearray(total)
                take = min(total, self._wpos - self._rpos)
                if take:
                    src = memoryview(self._scratch)
                    payload[:take] = src[self._rpos:self._rpos + take]
                    src.release()
                    self._rpos += take
                if take < total:     # the rest recv_intos straight in
                    self._payload = payload
                    self._payload_fill = take
                    self._payload_req = req
                    break
                self._dispatch(req, payload)
                continue
            self._dispatch(req, None)
        if self._rpos == self._wpos:
            self._rpos = self._wpos = 0

    # -- request dispatch ----------------------------------------------------
    def _spawn(self, coro) -> None:
        task = asyncio.ensure_future(coro)
        self._tasks.add(task)
        task.add_done_callback(self._tasks.discard)

    def _reject(self, req: dict, error: str) -> None:
        # the announced payload was never consumed, so the stream cannot
        # be resynced: stop parsing NOW or the payload bytes would be
        # interpreted as frames (and could decode into real ops)
        self._dead = True

        async def _send_and_close() -> None:
            try:
                await self.kv._send(self._writer, self._lock, {
                    "ok": False, "seq": req.get("seq"), "error": error})
            finally:
                self._writer.close()

        self._spawn(_send_and_close())

    def _dispatch(self, req: dict, payload) -> None:
        if req.get("op") == "shutdown":
            self.kv._n_ops += 1
            self.kv._shutdown.set()

            async def _ack_and_close() -> None:
                try:
                    await self.kv._send(self._writer, self._lock,
                                        {"ok": True, "seq": req.get("seq")})
                finally:
                    self._writer.close()

            self._spawn(_ack_and_close())
            return
        # inline fast path: ops with no await points are answered straight
        # from the read callback — no task spawn, no drain round.  Writes
        # here cannot tear a task's locked response: _send's write pairs
        # have no await between them, and this runs on the same loop.
        # Only taken while NO task is in flight on this connection — an
        # earlier request still on a task (e.g. a persisted put2) must
        # land its memory write before a later read is answered, or the
        # submission-order guarantee breaks.
        if not self._tasks:
            sync = self.kv.try_sync(req, payload)
            if sync is not None:
                resp, raw = sync
                seq = req.get("seq")
                if seq is not None:
                    resp["seq"] = seq
                body = msgpack.packb(resp, use_bin_type=True)
                w = self._writer
                w.write(_LEN.pack(len(body)) + body)
                if raw:
                    for blob in raw:
                        w.write(blob)
                return
        # tasks preserve submission order for their synchronous prefixes
        # (dict reads/writes) but let slow ops (persist, sleep, parked
        # waits) complete out of order behind fast ones
        self._spawn(self.kv._handle_one(req, payload, self._writer,
                                        self._lock))


async def _expiry_backstop(kv: KVServer) -> None:
    """Periodic lease sweep: expires keys even on an idle server (the lazy
    per-request sweep only runs while requests arrive)."""
    while True:
        await asyncio.sleep(KVServer.SWEEP_INTERVAL)
        kv._maybe_sweep()


async def serve(host: str, port: int, persist_dir: str | None,
                ready_file: str | None) -> None:
    kv = KVServer(persist_dir)
    loop = asyncio.get_running_loop()
    if is_uds(host):
        path = uds_path(host)
        with contextlib.suppress(OSError):
            os.unlink(path)     # stale socket from a killed predecessor
        server = await loop.create_unix_server(
            lambda: KVIngestProtocol(kv), path)
        actual_port = 0
    else:
        server = await loop.create_server(lambda: KVIngestProtocol(kv),
                                          host, port)
        actual_port = server.sockets[0].getsockname()[1]
    if ready_file:
        tmp = Path(ready_file + ".tmp")
        # host may itself contain ':' (unix:/path) — readers rsplit;
        # one-time startup write, no clients yet  # lint: blocking-ok
        tmp.write_text(f"{host}:{actual_port}:{os.getpid()}")
        tmp.replace(ready_file)
    sweeper = asyncio.create_task(_expiry_backstop(kv))
    try:
        async with server:
            await kv._shutdown.wait()
    finally:
        sweeper.cancel()


def spawn_server(*, host: str = "127.0.0.1", port: int = 0,
                 persist_dir: str | None = None,
                 ready_file: str, timeout: float = 20.0) -> tuple[str, int, int]:
    """Launch a KV server subprocess; block until it publishes its address.

    Returns (host, port, pid).
    """
    cmd = [sys.executable, "-m", "repro.core.kv_tcp", "--host", host,
           "--port", str(port), "--ready-file", ready_file]
    if persist_dir:
        cmd += ["--persist-dir", persist_dir]
    env = dict(os.environ)
    # the child must import repro even when the parent got it via sys.path
    # manipulation (e.g. tests' conftest) rather than an installed package
    src_dir = str(Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src_dir, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL,
                            start_new_session=True)
    # monotonic: a wall-clock step during startup must not cut the
    # connect-retry window short (or extend it unboundedly)
    deadline = time.monotonic() + timeout
    path = Path(ready_file)
    while time.monotonic() < deadline:
        if path.exists():
            # rsplit: the host part may be a unix:/path address with ':'s
            h, p, pid = path.read_text().rsplit(":", 2)
            return h, int(p), int(pid)
        if proc.poll() is not None:
            raise RuntimeError(f"kv server died at startup (rc={proc.returncode})")
        time.sleep(0.02)
    proc.kill()
    raise TimeoutError("kv server did not start in time")


# ---------------------------------------------------------------------------
# pipelined client
# ---------------------------------------------------------------------------
class _Conn:
    """One live connection: socket + pending futures + its reader thread."""

    __slots__ = ("sock", "pending", "send_lock", "seq")

    def __init__(self, sock: socket.socket) -> None:
        self.sock = sock
        self.pending: dict[int, Future] = {}
        self.send_lock = threading.Lock()
        self.seq = itertools.count(1)


def _chain(fut: Future, fn) -> Future:
    """Future that resolves to ``fn(fut.result())``."""
    out: Future = Future()

    def _done(f: Future) -> None:
        try:
            out.set_result(fn(f.result()))
        except BaseException as e:  # noqa: BLE001 - propagate into future
            out.set_exception(e)

    fut.add_done_callback(_done)
    return out


class KVClient:
    """Blocking client with a pipelined, multiplexed connection.

    ``submit`` tags each request with a ``seq``, sends it without waiting,
    and returns a ``Future``; a background reader thread completes futures
    as (possibly out-of-order) responses arrive.  Any number of threads may
    have requests in flight on the one socket — batched workloads pay ~1
    round trip instead of N.  Sync methods (``put``/``get``/...) are thin
    wrappers that submit and wait.

    On connection loss every pending future fails with ``ConnectionError``
    and the next request transparently reconnects.  Ops in
    :data:`IDEMPOTENT_OPS` are additionally re-issued through that
    reconnect path, paced by ``retry_policy``; mutating ops
    (``put2``/``incref``/``s_append``...) stay fail-fast so a retry can
    never double-commit.  ``host`` may be ``unix:/path`` for a
    Unix-domain server (``port`` is carried but unused).
    """

    def __init__(self, host: str, port: int, timeout: float = 60.0,
                 retry_policy: RetryPolicy | None = None) -> None:
        self.host, self.port, self.timeout = host, port, timeout
        # snappier than the RetryPolicy defaults: a client-side retry sits
        # on the failover read path, where 0.2 s base backoff would
        # dominate recovery time
        self.retry_policy = retry_policy or RetryPolicy(
            max_attempts=2, base_delay_s=0.05, max_delay_s=1.0)
        self._lock = threading.Lock()     # guards _conn lifecycle
        self._conn: _Conn | None = None
        self._closed = False
        self.n_reconnects = 0   # connections established (first connect = 1)
        self.n_retries = 0      # idempotent ops re-issued after a conn loss
        self.n_tx_bytes = 0     # bytes written to the socket (frames +
        # payloads) — the fig16 client-egress accounting: chain replication
        # should cut a replicated put's client bytes to ~1/R of the
        # client-uploads-every-copy baseline

    # -- connection lifecycle ------------------------------------------------
    def _connect_locked(self) -> _Conn:
        if self._conn is None:
            if self._closed:
                raise ConnectionError("client is closed")
            if is_uds(self.host):
                s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                s.settimeout(self.timeout)
                try:
                    s.connect(uds_path(self.host))
                except OSError as e:
                    s.close()
                    raise ConnectionError(
                        f"kv connect failed: {self.host}: {e}") from e
            else:
                s = socket.create_connection((self.host, self.port),
                                             timeout=self.timeout)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            try:
                s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, _SOCKBUF)
                s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, _SOCKBUF)
            except OSError:  # pragma: no cover
                pass
            s.settimeout(None)  # the reader thread blocks until data/close
            conn = _Conn(s)
            t = threading.Thread(target=self._reader_loop, args=(conn,),
                                 name=f"kv-reader-{self.host}:{self.port}",
                                 daemon=True)
            t.start()
            self._conn = conn
            self.n_reconnects += 1
        return self._conn

    def _drop(self, conn: _Conn, exc: BaseException | None = None) -> None:
        """Tear down ``conn``: fail its pending futures, forget it if it is
        still the live connection."""
        with self._lock:
            if self._conn is conn:
                self._conn = None
            pending = list(conn.pending.values())
            conn.pending.clear()
        try:
            conn.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        err = ConnectionError(f"kv connection lost: {exc}" if exc
                              else "kv connection closed")
        for fut in pending:
            if not fut.done():
                fut.set_exception(err)

    # -- reader thread -------------------------------------------------------
    def _reader_loop(self, conn: _Conn) -> None:
        bsock = _BufferedSock(conn.sock)
        try:
            while True:
                (length,) = _LEN.unpack(bsock.read_view(4))
                resp = msgpack.unpackb(bsock.read_view(length), raw=False,
                                       strict_map_key=False)
                nraw = resp.pop("raw", None)
                if nraw is not None:
                    if nraw < 0:
                        resp["data"] = None
                    else:
                        buf = bytearray(nraw)
                        bsock.readinto(memoryview(buf))
                        resp["data"] = memoryview(buf)
                raws = resp.pop("raws", None)
                if raws is not None:
                    # one buffer per blob (not one shared slab): a cached
                    # zero-copy view of one object must not pin the whole
                    # batch's bytes in memory
                    out: list[memoryview | None] = []
                    for n in raws:
                        if n < 0:
                            out.append(None)
                        else:
                            buf = bytearray(n)
                            if n:
                                bsock.readinto(memoryview(buf))
                            out.append(memoryview(buf))
                    resp["data"] = out
                with self._lock:
                    fut = conn.pending.pop(resp.get("seq"), None)
                if fut is not None and not fut.done():
                    fut.set_result(resp)
        except BaseException as e:  # noqa: BLE001 - ANY reader death must
            # fail the pending futures and drop the connection, or every
            # later request on this client would hang to its timeout
            self._drop(conn, e)

    # -- request submission --------------------------------------------------
    def submit(self, msg: dict, payload=None) -> Future:
        """Pipelined send: returns a Future of the response map.

        ``payload`` (optional) is a sequence of raw segments gather-written
        immediately after the request frame (``put2``/``mput2``).
        """
        with self._lock:
            conn = self._connect_locked()
            msg["seq"] = seq = next(conn.seq)
            fut: Future = Future()
            fut._kv_conn, fut._kv_seq = conn, seq  # for timeout cleanup
            conn.pending[seq] = fut
        body = msgpack.packb(msg, use_bin_type=True)
        segments = [_LEN.pack(len(body)) + body]
        if payload is not None:
            segments.extend(payload)
        self.n_tx_bytes += sum(memoryview(s).nbytes for s in segments)
        try:
            with conn.send_lock:
                send_segments_sync(conn.sock, segments)
        except (ConnectionError, OSError) as e:
            self._drop(conn, e)
            raise ConnectionError(f"kv send failed: {e}") from e
        return fut

    def request(self, msg: dict, payload=None,
                timeout: float | None = None,
                retry: bool | None = None) -> dict:
        """Send a framed request and wait for its response.

        ``retry=None`` (the default) classifies by op: members of
        :data:`IDEMPOTENT_OPS` are re-issued through the transparent-
        reconnect path on a lost connection, paced by ``retry_policy``
        (exponential backoff, jittered); everything else — puts, refcount
        mutations, ``s_append``, consuming ``s_next`` — fails fast, since
        the server may have committed the effect before the link died.
        Pass an explicit bool to override the classification.
        If the response carried an out-of-band payload it is surfaced as
        ``resp["data"]`` (a writable memoryview; None for missing).
        ``timeout`` overrides the client default for ops that park
        server-side (``wait``/``mwait``/``s_next``) longer than it.
        """
        if retry is None:
            retry = msg.get("op") in IDEMPOTENT_OPS
        elif retry and msg.get("op") not in IDEMPOTENT_OPS:
            # a forced retry on a non-idempotent op can double-commit the
            # effect (double put, double decref, duplicated stream item) —
            # under the sanitizer that is a hard error, not a footgun
            from repro.analysis import sanitize as _san

            if _san.enabled():
                raise _san.SanitizerError(
                    "non-idempotent-retry",
                    f"op {msg.get('op')!r} forced retry=True: the server "
                    f"may have committed the effect before the link died, "
                    f"so re-issuing can double-apply it.  Retry only "
                    f"members of IDEMPOTENT_OPS.")
        policy = self.retry_policy
        attempts = max(1, policy.max_attempts) if retry else 1
        start = time.monotonic()
        for attempt in range(attempts):
            fut = None
            try:
                fut = self.submit(msg, payload)
                return fut.result(self.timeout if timeout is None
                                  else timeout)
            except ConnectionError:
                if attempt + 1 >= attempts:
                    raise
                self.n_retries += 1
                if attempt:     # first retry is immediate: the server is
                    # usually back (restart) or a replica will take the op;
                    # back off only once reconnect itself keeps failing
                    delay = policy.delay_for(attempt - 1)
                    if policy.expired(start, delay):
                        raise   # the retry budget is spent: fail now
                    time.sleep(delay)
            except FuturesTimeout:
                # unregister the abandoned request so the entry (and its
                # eventual response buffer) can't pile up on a long-lived
                # connection; a late response for the seq is then dropped
                with self._lock:
                    fut._kv_conn.pending.pop(fut._kv_seq, None)
                raise
        raise ConnectionError("unreachable")

    # -- convenience ops -----------------------------------------------------
    def put(self, key: str, data) -> None:
        """Store ``data`` (bytes | Frame | segment sequence) under ``key``.

        Multi-segment frames are gather-written after the header — the
        client never joins them into one bytes object.
        """
        resp = self.request(*self._put_msg(key, data))
        if not resp["ok"]:
            raise RuntimeError(resp.get("error"))

    def put_async(self, key: str, data) -> Future:
        """Pipelined put: returns ``Future[None]``; raises on failure."""
        return _chain(self.submit(*self._put_msg(key, data)), _check_ok)

    def _put_msg(self, key: str, data) -> tuple[dict, list]:
        from repro.core.serialize import as_segments, frame_nbytes

        nbytes = frame_nbytes(data)
        if nbytes > MAX_FRAME:
            # fail before streaming gigabytes the server will reject
            raise ValueError(f"payload too large: {nbytes} > {MAX_FRAME}")
        return {"op": "put2", "key": key, "nbytes": nbytes}, as_segments(data)

    def put_chain(self, key: str, data, chain=(),
                  hint_for: str | None = None) -> dict:
        """Replicated put, server-side: upload ONE copy; the receiving
        shard forwards it to each ``chain`` successor with per-hop acks.
        ``hint_for`` marks this put as hinted handoff — the receiver
        records that ``hint_for`` (the suspect intended owner) is owed the
        key, replayed via :meth:`hint_replay` on recovery.  Returns the
        raw response (``chain_acks``/``chain_errors``) so the caller can
        queue repairs for unreachable successors."""
        msg, payload = self._put_msg(key, data)
        if chain:
            msg["chain"] = [str(a) for a in chain]
        if hint_for:
            msg["hint_for"] = str(hint_for)
        resp = self.request(msg, payload=payload, retry=False)
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return resp

    def get(self, key: str):
        """Return the payload as a writable memoryview, or None."""
        return self.request({"op": "get2", "key": key}).get("data")

    def get_async(self, key: str) -> Future:
        """Pipelined get: ``Future[memoryview | None]``."""
        return _chain(self.submit({"op": "get2", "key": key}),
                      lambda r: r.get("data"))

    def mput(self, keys, blobs) -> None:
        """Batch put in ONE exchange: raw segments streamed back to back."""
        from repro.core.serialize import as_segments, frame_nbytes

        sizes = [frame_nbytes(b) for b in blobs]
        if sum(sizes) > MAX_FRAME:
            raise ValueError(f"batch too large: {sum(sizes)} > {MAX_FRAME}")
        segments = [seg for b in blobs for seg in as_segments(b)]
        resp = self.request({"op": "mput2", "keys": list(keys),
                             "nbytes": sizes}, payload=segments)
        if not resp["ok"]:
            raise RuntimeError(resp.get("error"))

    def mput_async(self, keys, blobs) -> Future:
        """Pipelined batch put: ``Future[None]`` for the whole batch (the
        fabric submits one of these per shard, concurrently)."""
        from repro.core.serialize import as_segments, frame_nbytes

        sizes = [frame_nbytes(b) for b in blobs]
        if sum(sizes) > MAX_FRAME:
            raise ValueError(f"batch too large: {sum(sizes)} > {MAX_FRAME}")
        segments = [seg for b in blobs for seg in as_segments(b)]
        return _chain(self.submit({"op": "mput2", "keys": list(keys),
                                   "nbytes": sizes}, payload=segments),
                      _check_ok)

    def mput_chain_async(self, keys, blobs, chain=(),
                         hint_for: str | None = None) -> Future:
        """Pipelined chain-replicated batch put: ``Future[resp]`` — the
        raw response map, so the caller inspects ``chain_errors`` (the
        pipeline queues repairs for failed hops instead of failing the
        batch)."""
        from repro.core.serialize import as_segments, frame_nbytes

        sizes = [frame_nbytes(b) for b in blobs]
        if sum(sizes) > MAX_FRAME:
            raise ValueError(f"batch too large: {sum(sizes)} > {MAX_FRAME}")
        segments = [seg for b in blobs for seg in as_segments(b)]
        msg = {"op": "mput2", "keys": list(keys), "nbytes": sizes}
        if chain:
            msg["chain"] = [str(a) for a in chain]
        if hint_for:
            msg["hint_for"] = str(hint_for)
        return self.submit(msg, payload=segments)

    def mget(self, keys) -> list:
        """Batch get in ONE exchange; memoryview per present key, else None."""
        return self.mget_async(keys).result(self.timeout)

    def mget_async(self, keys) -> Future:
        return _chain(self.submit({"op": "mget2", "keys": list(keys)}),
                      lambda r: r.get("data"))

    # -- futures: block until a producer lands the key -----------------------
    def wait(self, key: str, timeout: float = 60.0):
        """A blocking ``get`` for data that may not exist yet: parks
        server-side until the key's put lands, then returns the payload as
        a writable memoryview.  Raises ``TimeoutError`` if no producer
        shows up in ``timeout`` seconds."""
        resp = self.request({"op": "wait", "key": key, "timeout": timeout},
                            timeout=timeout + self.timeout)
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return resp.get("data")

    def wait_async(self, key: str, timeout: float = 60.0) -> Future:
        """Pipelined wait: ``Future[memoryview]`` (TimeoutError inside)."""
        return _chain(self.submit({"op": "wait", "key": key,
                                   "timeout": timeout}), _wait_data)

    def mwait(self, keys, timeout: float = 60.0) -> list:
        """Wait for ALL keys under one shared deadline, ONE exchange;
        returns a memoryview per key.  Raises TimeoutError if any key
        never arrived."""
        resp = self.request({"op": "mwait", "keys": list(keys),
                             "timeout": timeout},
                            timeout=timeout + self.timeout)
        if resp.get("timeout"):
            missing = [k for k, d in zip(keys, resp.get("data") or [])
                       if d is None]
            raise TimeoutError(f"mwait timed out on {missing}")
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return resp.get("data")

    # -- streams: per-topic append/consume -----------------------------------
    def stream_append(self, topic: str, data, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        """Append one item (bytes | Frame | segments) to ``topic``; returns
        its sequence number.  The item is stored refcounted — one
        reference per subscribed consumer group whose filter matches
        ``meta`` (legacy single reference on topics without groups).  On a
        topic with an ``s_limit`` bound the append parks server-side until
        consumer acks free a buffer slot (raises TimeoutError past
        ``timeout``)."""
        from repro.core.serialize import as_segments, frame_nbytes

        nbytes = frame_nbytes(data)
        if nbytes > MAX_FRAME:
            raise ValueError(f"payload too large: {nbytes} > {MAX_FRAME}")
        msg = {"op": "s_append", "topic": topic, "nbytes": nbytes}
        if ttl is not None:
            msg["ttl"] = ttl
        if meta:
            msg["meta"] = dict(meta)
        if timeout is not None:
            msg["timeout"] = timeout
        # never auto-retried: a reconnect-retry after the server committed
        # would append the item twice under a second sequence number
        resp = self.request(msg, payload=as_segments(data), retry=False,
                            timeout=(None if timeout is None
                                     else timeout + self.timeout))
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return int(resp["data"])

    # -- pub/sub consumer groups ---------------------------------------------
    def stream_sub(self, topic: str, group: str, start: str = "new",
                   filter: dict | None = None) -> dict:  # noqa: A002
        """Create (idempotently) consumer group ``group`` on ``topic``.
        ``start="begin"`` queues the retained items that pass ``filter``;
        ``"new"`` starts from the next append.  Returns the group state
        ``{"created", "queued", "count", "closed"}``."""
        msg = {"op": "s_sub", "topic": topic, "group": group, "start": start}
        if filter:
            msg["filter"] = filter
        return self._data_op(msg)

    def stream_unsub(self, topic: str, group: str) -> None:
        """Drop the group, releasing its outstanding payload references."""
        self._data_op({"op": "s_unsub", "topic": topic, "group": group})

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True) -> dict:
        """Block until an event is deliverable to ``group``; returns
        ``{"seq", "data", "meta", "available", "closed", "end",
        "missing"}`` (``data`` None for metadata-only takes and past-end
        markers).  The event stays unacked until :meth:`stream_ack`."""
        # delivery moves the event out of the group's queue: a reconnect-
        # retry could observe it as already delivered, so fail fast
        resp = self.request({"op": "s_next2", "topic": topic,
                             "group": group, "timeout": timeout,
                             "payload": payload},
                            timeout=timeout + self.timeout, retry=False)
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return {"seq": resp.get("i"), "data": resp.get("data"),
                "meta": resp.get("meta") or {},
                "available": int(resp.get("available", 0)),
                "closed": bool(resp.get("closed")),
                "end": bool(resp.get("end")),
                "missing": bool(resp.get("missing"))}

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True) -> list[dict]:
        """Non-blocking batch take: up to ``n`` deliverable events in ONE
        exchange, each ``{"seq", "data", "meta"}`` (``data`` None for
        metadata-only takes).  Events stay unacked until acked."""
        resp = self.request({"op": "s_fetch", "topic": topic,
                             "group": group, "n": int(n),
                             "payload": payload}, retry=False)
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        seqs = resp.get("seqs") or []
        metas = resp.get("metas") or [{}] * len(seqs)
        datas = resp.get("data") or [None] * len(seqs)
        return [{"seq": int(s), "meta": m or {}, "data": d}
                for s, m, d in zip(seqs, metas, datas)]

    def stream_ack(self, topic: str, group: str, seqs) -> int:
        """Ack delivered events for ``group`` — releases each event's
        group reference (payload evicted after the LAST group acks) and
        frees backpressure credits.  Returns how many were newly acked."""
        return int(self._data_op({"op": "s_ack", "topic": topic,
                                  "group": group,
                                  "seqs": [int(s) for s in seqs]}) or 0)

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None) -> int:
        """Hand delivered-but-unprocessed events back to the group (they
        redeliver in sequence order).  Returns how many were requeued.
        Events already delivered ``max_deliveries`` times are NOT requeued
        — they move to ``<topic>.dlq`` with failure metadata (``reason``
        rides into the DLQ record)."""
        msg = {"op": "s_requeue", "topic": topic, "group": group,
               "seqs": [int(s) for s in seqs]}
        if reason:
            msg["reason"] = reason
        return int(self._data_op(msg) or 0)

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None) -> None:
        """Bound the topic's buffer of unacked events (credit-based
        backpressure); falsy ``limit`` clears the bound.
        ``max_deliveries`` (kept independently; None leaves it untouched,
        0 clears) bounds redeliveries per (group, event) before the event
        is dead-lettered to ``<topic>.dlq``."""
        msg = {"op": "s_limit", "topic": topic, "limit": limit}
        if max_deliveries is not None:
            msg["max_deliveries"] = max_deliveries
        self._data_op(msg)

    # -- durability: replica chains, snapshots, hinted handoff ---------------
    def stream_chain(self, topic: str, chain) -> None:
        """Install the topic's replica chain on its home shard: group-state
        mutations push cursor snapshots there, appends forward payloads.
        Empty ``chain`` clears it."""
        self._data_op({"op": "s_chain", "topic": topic,
                       "chain": [str(a) for a in chain]})

    def stream_snap(self, topic: str) -> dict:
        """The topic's full replicated broker state (see ``s_snap``)."""
        return dict(self._data_op({"op": "s_snap", "topic": topic}) or {})

    def stream_restore(self, topic: str, state: dict) -> None:
        """Install a snapshot wholesale on this shard (see ``s_restore``)."""
        self._data_op({"op": "s_restore", "topic": topic,
                       "state": state})

    def stream_drop(self, topic: str) -> None:
        """Forget the topic and evict its payload keys on this shard (the
        tail of a rebalance move)."""
        self._data_op({"op": "s_drop", "topic": topic})

    def hints(self) -> dict:
        """Pending hinted-handoff records: ``{owner_addr: [keys]}``."""
        return dict(self._data_op({"op": "hints"}) or {})

    def hint_replay(self, owner: str) -> int:
        """Replay this shard's hinted keys to the recovered ``owner``
        (bytes + refcount + remaining lease); returns how many keys were
        replayed.  Failed replays keep their hints for a later attempt."""
        out = self._data_op({"op": "hint_replay", "owner": owner})
        return int((out or {}).get("replayed", 0))

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    consume: bool = True) -> dict:
        """Block until item ``seq`` exists (or the stream closes); returns
        ``{"data": memoryview | None, "available": int, "end": bool}``.
        ``end`` means the stream closed before ``seq``.  The served item is
        consumed (decref'd server-side) unless ``consume=False``."""
        # consume=True is not idempotent (the server decrefs/evicts the
        # item when serving it): a reconnect-retry would find it missing
        resp = self.request({"op": "s_next", "topic": topic, "i": int(seq),
                             "timeout": timeout, "consume": consume},
                            timeout=timeout + self.timeout,
                            retry=not consume)
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return {"data": resp.get("data"),
                "available": int(resp.get("available", 0)),
                "end": bool(resp.get("end")),
                "closed": bool(resp.get("closed")),
                "missing": bool(resp.get("missing"))}

    def stream_fetch(self, topic: str, seqs) -> list:
        """Batch-consume already-available items: ONE ``mget2`` for the
        blobs + ONE ``mdecref`` marking them consumed (refcount hits zero,
        the server evicts them exactly once)."""
        keys = [stream_item_key(topic, int(s)) for s in seqs]
        if not keys:
            return []
        blobs = self.mget(keys)
        self.mdecref(keys)
        return blobs

    def stream_close(self, topic: str) -> None:
        """Set the end-of-stream marker; every parked consumer is
        released (they observe ``end`` once past the last item)."""
        self._data_op({"op": "s_close", "topic": topic})

    def stream_stat(self, topic: str) -> dict:
        return self._data_op({"op": "s_stat", "topic": topic})

    def exists(self, key: str) -> bool:
        return bool(self.request({"op": "exists", "key": key}).get("data"))

    def exists_async(self, key: str) -> Future:
        return _chain(self.submit({"op": "exists", "key": key}),
                      lambda r: bool(r.get("data")))

    def mexists(self, keys) -> list[bool]:
        resp = self.request({"op": "mexists", "keys": list(keys)})
        return [bool(x) for x in resp.get("data") or []]

    def evict(self, key: str) -> None:
        self.request({"op": "evict", "key": key})

    def mevict(self, keys) -> None:
        self.request({"op": "mevict", "keys": list(keys)})

    # -- lifecycle: refcounts + leases ---------------------------------------
    def _data_op(self, msg: dict):
        resp = self.request(msg)
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return resp.get("data")

    def incref(self, key: str, n: int = 1) -> int:
        """Add ``n`` references to ``key``; returns the new count."""
        return int(self._data_op({"op": "incref", "key": key, "n": n}))

    def decref(self, key: str, n: int = 1) -> int:
        """Drop ``n`` references; at zero the server evicts the key."""
        return int(self._data_op({"op": "decref", "key": key, "n": n}))

    def mincref(self, keys, n: int = 1) -> list[int]:
        """Batch incref in ONE exchange; returns the new counts."""
        return [int(c) for c in
                self._data_op({"op": "mincref", "keys": list(keys), "n": n})]

    def mdecref(self, keys, n: int = 1) -> list[int]:
        return [int(c) for c in
                self._data_op({"op": "mdecref", "keys": list(keys), "n": n})]

    def refcount(self, key: str) -> int:
        return int(self._data_op({"op": "refcount", "key": key}))

    def refsnap(self) -> dict[str, int]:
        """Full server refcount table (sanitizer close-time cross-check)."""
        return dict(self._data_op({"op": "refsnap"}) or {})

    def touch(self, key: str, ttl: float | None) -> bool:
        """Set/refresh (or clear, for ttl None/<=0) a TTL lease on ``key``;
        returns whether the key currently exists."""
        return bool(self._data_op({"op": "touch", "key": key, "ttl": ttl}))

    def mtouch(self, keys, ttl: float | None) -> None:
        self._data_op({"op": "mtouch", "keys": list(keys), "ttl": ttl})

    def ping(self) -> bool:
        try:
            return self.request({"op": "ping"}).get("data") == "pong"
        except (ConnectionError, OSError, TimeoutError, FuturesTimeout):
            return False

    def stats(self) -> dict:
        return self.request({"op": "stats"}).get("data") or {}

    def keyspace(self) -> dict:
        """Rebalance snapshot: ``{"keys": [...], "refs": {k: n},
        "leases": {k: seconds_remaining}}`` (stream items excluded)."""
        return self.request({"op": "keyspace"}).get("data") or {}

    def shutdown_server(self) -> None:
        try:
            self.request({"op": "shutdown"})
        except (ConnectionError, OSError):
            pass

    def close(self) -> None:
        with self._lock:
            self._closed = True
            conn, self._conn = self._conn, None
        if conn is not None:
            self._drop(conn)


def _check_ok(resp: dict) -> None:
    if not resp.get("ok"):
        raise RuntimeError(resp.get("error"))


def _wait_data(resp: dict):
    if resp.get("timeout"):
        raise TimeoutError(resp.get("error"))
    if not resp.get("ok"):
        raise RuntimeError(resp.get("error"))
    return resp.get("data")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--persist-dir", default=None)
    ap.add_argument("--ready-file", default=None)
    args = ap.parse_args()
    asyncio.run(serve(args.host, args.port, args.persist_dir, args.ready_file))


if __name__ == "__main__":
    main()
