"""Asyncio TCP key-value server + blocking client.

Plays two roles from the paper:

* the per-node storage servers spawned by the ZMQ/Margo/UCX connectors
  (§4.1.3: "these connectors act as interfaces to these spawned servers"),
* the Redis-style standalone hybrid store (§4.1.2) when started with
  ``--persist-dir`` (write-through to disk, reload on restart).

Wire format: 4-byte big-endian length | msgpack map.
Requests:  {"op": put|get|exists|evict|mput|mget|ping|stats|shutdown,
            "key": str, "data": bytes, "keys": [...], "blobs": [...]}
Responses: {"ok": bool, "data": ..., "error": str}

Bulk ops carry the payload *out of band* so multi-segment frames never pay a
join or msgpack copy:

* ``put2``: header {"op": "put2", "key": k, "nbytes": n} followed by n raw
  bytes on the stream — the client scatter-gathers frame segments straight
  onto the socket (writev-style), the server reads them into one buffer.
* ``get2``: response header {"ok": True, "raw": n} (-1 = missing) followed by
  n raw bytes — the client receives into a preallocated buffer and returns a
  writable memoryview, ready for zero-copy deserialization.

The server is a single asyncio loop (as the paper's PS-endpoints are) — the
Fig 8 benchmark reproduces the resulting linear scaling with client count.
"""
from __future__ import annotations

import argparse
import asyncio
import os
import socket
import struct
import subprocess
import sys
import threading
import time
from pathlib import Path

import msgpack

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 31


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------
async def read_frame(reader: asyncio.StreamReader) -> dict | None:
    try:
        header = await reader.readexactly(4)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    (length,) = _LEN.unpack(header)
    if length > MAX_FRAME:
        raise ValueError(f"frame too large: {length}")
    body = await reader.readexactly(length)
    return msgpack.unpackb(body, raw=False, strict_map_key=False)


def write_frame_sync(sock: socket.socket, msg: dict) -> None:
    body = msgpack.packb(msg, use_bin_type=True)
    sock.sendall(_LEN.pack(len(body)) + body)


def send_segments_sync(sock: socket.socket, segments) -> None:
    """Gather-write raw payload segments (no user-space join)."""
    for seg in segments:
        sock.sendall(seg)


def read_frame_sync(sock: socket.socket) -> dict:
    header = _recv_exact(sock, 4)
    (length,) = _LEN.unpack(header)
    body = _recv_exact(sock, length)
    return msgpack.unpackb(body, raw=False, strict_map_key=False)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_exact_into(sock: socket.socket, view: memoryview) -> None:
    while view.nbytes:
        n = sock.recv_into(view)
        if not n:
            raise ConnectionError("peer closed connection")
        view = view[n:]


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------
class KVServer:
    def __init__(self, persist_dir: str | None = None) -> None:
        self._data: dict[str, bytes] = {}
        self._persist = Path(persist_dir) if persist_dir else None
        self._n_ops = 0
        if self._persist:
            self._persist.mkdir(parents=True, exist_ok=True)
            for f in self._persist.glob("*.kv"):
                self._data[f.stem] = f.read_bytes()
        self._shutdown = asyncio.Event()

    # -- op handlers --------------------------------------------------------
    def _put(self, key: str, data: bytes) -> None:
        self._data[key] = data
        if self._persist:
            tmp = self._persist / f".{key}.tmp"
            tmp.write_bytes(data)
            tmp.replace(self._persist / f"{key}.kv")

    def _evict(self, key: str) -> None:
        self._data.pop(key, None)
        if self._persist:
            (self._persist / f"{key}.kv").unlink(missing_ok=True)

    def handle(self, req: dict) -> dict:
        self._n_ops += 1
        op = req["op"]
        if op == "put":
            self._put(req["key"], req["data"])
            return {"ok": True}
        if op == "get":
            data = self._data.get(req["key"])
            return {"ok": True, "data": data}
        if op == "exists":
            return {"ok": True, "data": req["key"] in self._data}
        if op == "evict":
            self._evict(req["key"])
            return {"ok": True}
        if op == "mput":
            for k, b in zip(req["keys"], req["blobs"]):
                self._put(k, b)
            return {"ok": True}
        if op == "mget":
            return {"ok": True, "data": [self._data.get(k) for k in req["keys"]]}
        if op == "ping":
            return {"ok": True, "data": "pong"}
        if op == "stats":
            return {"ok": True, "data": {
                "n_objects": len(self._data),
                "bytes": sum(len(v) for v in self._data.values()),
                "n_ops": self._n_ops,
            }}
        if op == "shutdown":
            self._shutdown.set()
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    async def client_loop(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                req = await read_frame(reader)
                if req is None:
                    break
                op = req.get("op")
                if op == "put2":
                    # out-of-band payload: header first, then raw bytes
                    nbytes = int(req["nbytes"])
                    if nbytes > MAX_FRAME:
                        # can't resync the stream without consuming the
                        # payload; report the reason, then drop the conn
                        body = msgpack.packb(
                            {"ok": False,
                             "error": f"payload too large: {nbytes}"},
                            use_bin_type=True)
                        writer.write(_LEN.pack(len(body)) + body)
                        await writer.drain()
                        break
                    data = await reader.readexactly(nbytes) if nbytes else b""
                    self._n_ops += 1
                    try:
                        self._put(req["key"], data)
                        resp = {"ok": True}
                    except Exception as e:  # noqa: BLE001 - surface to client
                        resp = {"ok": False, "error": str(e)}
                elif op == "get2":
                    self._n_ops += 1
                    data = self._data.get(req["key"])
                    resp = {"ok": True,
                            "raw": -1 if data is None else len(data)}
                    body = msgpack.packb(resp, use_bin_type=True)
                    writer.write(_LEN.pack(len(body)) + body)
                    if data is not None:
                        writer.write(data)
                    await writer.drain()
                    continue
                else:
                    resp = self.handle(req)
                body = msgpack.packb(resp, use_bin_type=True)
                writer.write(_LEN.pack(len(body)) + body)
                await writer.drain()
                if op == "shutdown":
                    break
        finally:
            writer.close()


async def serve(host: str, port: int, persist_dir: str | None,
                ready_file: str | None) -> None:
    kv = KVServer(persist_dir)
    server = await asyncio.start_server(kv.client_loop, host, port)
    actual_port = server.sockets[0].getsockname()[1]
    if ready_file:
        tmp = Path(ready_file + ".tmp")
        tmp.write_text(f"{host}:{actual_port}:{os.getpid()}")
        tmp.replace(ready_file)
    async with server:
        await kv._shutdown.wait()


def spawn_server(*, host: str = "127.0.0.1", persist_dir: str | None = None,
                 ready_file: str, timeout: float = 20.0) -> tuple[str, int, int]:
    """Launch a KV server subprocess; block until it publishes its address.

    Returns (host, port, pid).
    """
    cmd = [sys.executable, "-m", "repro.core.kv_tcp", "--host", host,
           "--port", "0", "--ready-file", ready_file]
    if persist_dir:
        cmd += ["--persist-dir", persist_dir]
    env = dict(os.environ)
    # the child must import repro even when the parent got it via sys.path
    # manipulation (e.g. tests' conftest) rather than an installed package
    src_dir = str(Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src_dir, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL,
                            start_new_session=True)
    deadline = time.time() + timeout
    path = Path(ready_file)
    while time.time() < deadline:
        if path.exists():
            h, p, pid = path.read_text().split(":")
            return h, int(p), int(pid)
        if proc.poll() is not None:
            raise RuntimeError(f"kv server died at startup (rc={proc.returncode})")
        time.sleep(0.02)
    proc.kill()
    raise TimeoutError("kv server did not start in time")


# ---------------------------------------------------------------------------
# blocking client (thread-safe via lock; one socket per client)
# ---------------------------------------------------------------------------
class KVClient:
    def __init__(self, host: str, port: int, timeout: float = 60.0) -> None:
        self.host, self.port, self.timeout = host, port, timeout
        self._lock = threading.Lock()
        self._sock: socket.socket | None = None

    def _connect(self) -> socket.socket:
        if self._sock is None:
            s = socket.create_connection((self.host, self.port),
                                         timeout=self.timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = s
        return self._sock

    def request(self, msg: dict, payload=None) -> dict:
        """Send a framed request, optionally followed by raw payload segments.

        If the response header carries ``raw`` (an out-of-band payload
        length), the payload is received into a preallocated buffer and
        returned as ``resp["data"]`` (a writable memoryview; None for -1).
        """
        with self._lock:
            for attempt in (0, 1):
                try:
                    sock = self._connect()
                    write_frame_sync(sock, msg)
                    if payload is not None:
                        send_segments_sync(sock, payload)
                    resp = read_frame_sync(sock)
                    nraw = resp.pop("raw", None)
                    if nraw is not None:
                        if nraw < 0:
                            resp["data"] = None
                        else:
                            buf = bytearray(nraw)
                            _recv_exact_into(sock, memoryview(buf))
                            resp["data"] = memoryview(buf)
                    return resp
                except (ConnectionError, OSError):
                    self._drop()
                    if attempt:
                        raise
            raise ConnectionError("unreachable")

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._drop()

    # convenience ops
    def put(self, key: str, data) -> None:
        """Store ``data`` (bytes | Frame | segment sequence) under ``key``.

        Multi-segment frames are gather-written after the header — the
        client never joins them into one bytes object.
        """
        from repro.core.serialize import as_segments, frame_nbytes

        nbytes = frame_nbytes(data)
        if nbytes > MAX_FRAME:
            # fail before streaming gigabytes the server will reject
            raise ValueError(f"payload too large: {nbytes} > {MAX_FRAME}")
        resp = self.request({"op": "put2", "key": key, "nbytes": nbytes},
                            payload=as_segments(data))
        if not resp["ok"]:
            raise RuntimeError(resp.get("error"))

    def get(self, key: str):
        """Return the payload as a writable memoryview, or None."""
        resp = self.request({"op": "get2", "key": key})
        return resp.get("data")

    def exists(self, key: str) -> bool:
        return bool(self.request({"op": "exists", "key": key}).get("data"))

    def evict(self, key: str) -> None:
        self.request({"op": "evict", "key": key})

    def ping(self) -> bool:
        try:
            return self.request({"op": "ping"}).get("data") == "pong"
        except (ConnectionError, OSError, TimeoutError):
            return False

    def shutdown_server(self) -> None:
        try:
            self.request({"op": "shutdown"})
        except (ConnectionError, OSError):
            pass


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--persist-dir", default=None)
    ap.add_argument("--ready-file", default=None)
    args = ap.parse_args()
    asyncio.run(serve(args.host, args.port, args.persist_dir, args.ready_file))


if __name__ == "__main__":
    main()
