"""Process-level deployment helpers for relay servers and PS-endpoints.

The paper manages endpoints with the ``proxystore-endpoint`` CLI; here the
same lifecycle is scripted for tests/benchmarks: spawn, await readiness,
terminate.  All children are started in their own session so killing the
parent never orphans a test run.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from dataclasses import dataclass
from pathlib import Path


@dataclass
class ProcHandle:
    proc: subprocess.Popen
    host: str
    port: int
    uuid: str | None = None

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def stop(self) -> None:
        if self.proc.poll() is None:
            try:
                os.killpg(self.proc.pid, signal.SIGTERM)
            except (ProcessLookupError, PermissionError):
                self.proc.terminate()
            try:
                self.proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                self.proc.kill()


def _spawn(module: str, args: list[str], ready_file: str,
           timeout: float = 30.0) -> tuple[subprocess.Popen, list[str]]:
    Path(ready_file).unlink(missing_ok=True)
    cmd = [sys.executable, "-m", module, *args, "--ready-file", ready_file]
    env = dict(os.environ)
    # children must import repro even when the parent got it via sys.path
    # manipulation (e.g. tests' conftest) rather than an installed package
    src_dir = str(Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src_dir, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL,
                            stderr=subprocess.PIPE,
                            start_new_session=True)
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if Path(ready_file).exists():
            return proc, Path(ready_file).read_text().split(":")
        if proc.poll() is not None:
            err = proc.stderr.read().decode() if proc.stderr else ""
            raise RuntimeError(f"{module} died at startup: {err[-2000:]}")
        time.sleep(0.02)
    proc.kill()
    raise TimeoutError(f"{module} did not become ready")


def start_relay(workdir: str) -> ProcHandle:
    ready = str(Path(workdir) / "relay.ready")
    proc, (host, port, _pid) = _spawn("repro.core.relay", [], ready)
    return ProcHandle(proc=proc, host=host, port=int(port))


def start_endpoint(workdir: str, relay_address: str, *, name: str = "ep",
                   persist_dir: str | None = None,
                   throttle_bps: float | None = None,
                   throttle_rtt: float = 0.0) -> ProcHandle:
    ready = str(Path(workdir) / f"{name}.ready")
    args = ["--relay", relay_address]
    if persist_dir:
        args += ["--persist-dir", persist_dir]
    if throttle_bps:
        args += ["--throttle-bps", str(throttle_bps)]
    if throttle_rtt:
        args += ["--throttle-rtt", str(throttle_rtt)]
    proc, fields = _spawn("repro.core.endpoint", args, ready)
    host, port, _pid, uuid = fields
    return ProcHandle(proc=proc, host=host, port=int(port), uuid=uuid)


def start_kvserver(workdir: str, *, name: str = "kv",
                   persist_dir: str | None = None,
                   uds: bool = False) -> ProcHandle:
    """Spawn one KV server.  ``uds=True`` binds a Unix-domain socket under
    ``workdir`` instead of loopback TCP — the fast same-host transport the
    sharded fabric uses (host is then ``unix:/path``, port 0)."""
    ready = str(Path(workdir) / f"{name}.ready")
    listen = f"unix:{Path(workdir) / (name + '.sock')}" if uds else "127.0.0.1"
    args = ["--host", listen, "--port", "0"]
    if persist_dir:
        args += ["--persist-dir", persist_dir]
    proc, fields = _spawn("repro.core.kv_tcp", args, ready)
    # re-join + rsplit: a unix:/path host itself contains ':'
    host, port, _pid = ":".join(fields).rsplit(":", 2)
    return ProcHandle(proc=proc, host=host, port=int(port))
