"""Transparent, lazy object proxies (the paper's §3.3).

A :class:`Proxy` wraps a *factory* — any zero-argument callable returning the
target object — and behaves identically to the target: ``isinstance(p,
type(target))`` holds, every attribute access / operator / dunder is forwarded,
and the factory is invoked at most once, just-in-time on first use
("resolving" the proxy).

Pickling a proxy serializes ONLY the factory (paper §3.3: "proxies are small
when communicated" and "a proxy can still be resolved after being communicated
to another process").

Implementation notes
--------------------
CPython resolves dunder methods on the *type*, not the instance, so
transparency requires every relevant ``__op__`` to exist on the Proxy class
and forward to the resolved target.  We generate those forwarders explicitly
(the same approach taken by ``lazy-object-proxy``, which the paper's
implementation builds on).

``__class__`` is a property returning ``type(target)`` which is what makes
``isinstance`` transparent without metaclass games.
"""
from __future__ import annotations

import operator
from typing import Any, Callable, Generic, TypeVar

T = TypeVar("T")

_UNRESOLVED = object()  # sentinel: target not yet materialized


class ProxyResolveError(RuntimeError):
    """Raised when a proxy's factory fails to produce the target."""


def _do_resolve(proxy: "Proxy") -> Any:
    """Resolve ``proxy`` in place (idempotent) and return the target."""
    target = object.__getattribute__(proxy, "_proxy_target")
    if target is not _UNRESOLVED:
        return target
    factory = object.__getattribute__(proxy, "_proxy_factory")
    try:
        target = factory()
    except Exception as e:  # noqa: BLE001 - surface context, keep cause
        raise ProxyResolveError(
            f"factory {factory!r} failed to resolve proxy target: {e}"
        ) from e
    object.__setattr__(proxy, "_proxy_target", target)
    return target


class Proxy(Generic[T]):
    """Lazy transparent proxy of the object returned by ``factory``."""

    __slots__ = ("_proxy_factory", "_proxy_target", "__weakref__")

    def __init__(self, factory: Callable[[], T]) -> None:
        if not callable(factory):
            raise TypeError(f"factory must be callable, got {type(factory)}")
        object.__setattr__(self, "_proxy_factory", factory)
        object.__setattr__(self, "_proxy_target", _UNRESOLVED)

    # -- pickling: factory only, never the target -------------------------
    def __reduce__(self):
        return (Proxy, (object.__getattribute__(self, "_proxy_factory"),))

    def __reduce_ex__(self, protocol):
        return self.__reduce__()

    # -- attribute protocol ------------------------------------------------
    def __getattr__(self, name: str) -> Any:
        # __slots__ attrs are found by __getattribute__; anything reaching
        # here is for the target.
        return getattr(_do_resolve(self), name)

    def __setattr__(self, name: str, value: Any) -> None:
        setattr(_do_resolve(self), name, value)

    def __delattr__(self, name: str) -> None:
        delattr(_do_resolve(self), name)

    # -- transparency: class/dir/repr/hash/eq etc. -------------------------
    @property  # type: ignore[misc]
    def __class__(self):  # noqa: D105
        return type(_do_resolve(self))

    def __dir__(self):
        return dir(_do_resolve(self))

    def __repr__(self) -> str:
        return repr(_do_resolve(self))

    def __str__(self) -> str:
        return str(_do_resolve(self))

    def __format__(self, spec: str) -> str:
        return format(_do_resolve(self), spec)

    def __hash__(self) -> int:
        return hash(_do_resolve(self))

    def __bool__(self) -> bool:
        return bool(_do_resolve(self))

    def __len__(self) -> int:
        return len(_do_resolve(self))

    def __iter__(self):
        return iter(_do_resolve(self))

    def __next__(self):
        return next(_do_resolve(self))

    def __reversed__(self):
        return reversed(_do_resolve(self))

    def __contains__(self, item) -> bool:
        return item in _do_resolve(self)

    def __getitem__(self, key):
        return _do_resolve(self)[key]

    def __setitem__(self, key, value) -> None:
        _do_resolve(self)[key] = value

    def __delitem__(self, key) -> None:
        del _do_resolve(self)[key]

    def __call__(self, *args, **kwargs):
        return _do_resolve(self)(*args, **kwargs)

    def __enter__(self):
        return _do_resolve(self).__enter__()

    def __exit__(self, *exc):
        return _do_resolve(self).__exit__(*exc)

    def __index__(self) -> int:
        return operator.index(_do_resolve(self))

    def __int__(self) -> int:
        return int(_do_resolve(self))

    def __float__(self) -> float:
        return float(_do_resolve(self))

    def __complex__(self) -> complex:
        return complex(_do_resolve(self))

    def __bytes__(self) -> bytes:
        return bytes(_do_resolve(self))

    # numpy/jax interop: let np.asarray(proxy) etc. see the target
    def __array__(self, *args, **kwargs):
        import numpy as np

        return np.asarray(_do_resolve(self), *args, **kwargs)

    @property
    def __array_interface__(self):
        return _do_resolve(self).__array_interface__

    def __jax_array__(self):
        import jax.numpy as jnp

        return jnp.asarray(_do_resolve(self))


def _forward_binary(name: str):
    op = getattr(operator, name, None)

    if op is not None:
        def fwd(self, other, _op=op):
            return _op(_do_resolve(self), _unwrap(other))
    else:
        def fwd(self, other, _name=f"__{name.strip('_')}__"):
            return getattr(_do_resolve(self), _name)(_unwrap(other))

    return fwd


def _forward_rbinary(dunder: str):
    def fwd(self, other):
        target = _do_resolve(self)
        meth = getattr(target, dunder, None)
        if meth is not None:
            return meth(_unwrap(other))
        return NotImplemented

    return fwd


def _forward_unary(dunder: str):
    def fwd(self):
        return getattr(_do_resolve(self), dunder)()

    return fwd


def _unwrap(obj):
    if type(obj) is Proxy:
        return _do_resolve(obj)
    return obj


_BINARY = {
    "__add__": "add", "__sub__": "sub", "__mul__": "mul",
    "__truediv__": "truediv", "__floordiv__": "floordiv", "__mod__": "mod",
    "__pow__": "pow", "__matmul__": "matmul", "__and__": "and_",
    "__or__": "or_", "__xor__": "xor", "__lshift__": "lshift",
    "__rshift__": "rshift", "__lt__": "lt", "__le__": "le", "__eq__": "eq",
    "__ne__": "ne", "__gt__": "gt", "__ge__": "ge", "__divmod__": None,
}
for dunder, opname in _BINARY.items():
    if opname is not None:
        op = getattr(operator, opname)

        def _mk(op):
            def fwd(self, other):
                return op(_do_resolve(self), _unwrap(other))
            return fwd

        setattr(Proxy, dunder, _mk(op))
    else:
        def _mkd(dunder):
            def fwd(self, other):
                return getattr(_do_resolve(self), dunder)(_unwrap(other))
            return fwd

        setattr(Proxy, dunder, _mkd(dunder))

for dunder in (
    "__radd__", "__rsub__", "__rmul__", "__rtruediv__", "__rfloordiv__",
    "__rmod__", "__rpow__", "__rmatmul__", "__rand__", "__ror__", "__rxor__",
    "__rlshift__", "__rrshift__", "__rdivmod__",
):
    setattr(Proxy, dunder, _forward_rbinary(dunder))

for dunder in ("__neg__", "__pos__", "__abs__", "__invert__", "__round__",
               "__trunc__", "__floor__", "__ceil__"):
    setattr(Proxy, dunder, _forward_unary(dunder))


# ---------------------------------------------------------------------------
# Module-level utilities (mirroring proxystore.proxy's API)
# ---------------------------------------------------------------------------

def is_resolved(proxy: Proxy) -> bool:
    """True if ``proxy``'s target has been materialized."""
    return object.__getattribute__(proxy, "_proxy_target") is not _UNRESOLVED


def resolve(proxy: Proxy) -> None:
    """Force resolution of ``proxy`` (no-op if already resolved)."""
    _do_resolve(proxy)


def extract(proxy: Proxy):
    """Return the target object of ``proxy``, resolving if necessary."""
    return _do_resolve(proxy)


def get_factory(proxy: Proxy) -> Callable[[], Any]:
    """Return the factory embedded in ``proxy``."""
    return object.__getattribute__(proxy, "_proxy_factory")


def is_proxy(obj: Any) -> bool:
    """True if ``obj`` is a Proxy instance (bypasses __class__ lie)."""
    return type(obj) is Proxy
