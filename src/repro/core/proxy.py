"""Transparent, lazy object proxies (the paper's §3.3).

A :class:`Proxy` wraps a *factory* — any zero-argument callable returning the
target object — and behaves identically to the target: ``isinstance(p,
type(target))`` holds, every attribute access / operator / dunder is forwarded,
and the factory is invoked at most once, just-in-time on first use
("resolving" the proxy).

Pickling a proxy serializes ONLY the factory (paper §3.3: "proxies are small
when communicated" and "a proxy can still be resolved after being communicated
to another process").

Implementation notes
--------------------
CPython resolves dunder methods on the *type*, not the instance, so
transparency requires every relevant ``__op__`` to exist on the Proxy class
and forward to the resolved target.  We generate those forwarders explicitly
(the same approach taken by ``lazy-object-proxy``, which the paper's
implementation builds on).

``__class__`` is a property returning ``type(target)`` which is what makes
``isinstance`` transparent without metaclass games.

Ownership (arXiv:2407.01764's proxy patterns)
---------------------------------------------
:class:`OwnedProxy` extends the transparent proxy with a *lifetime*: it holds
one reference to its target's storage and drops it (``release``) when the
proxy is garbage-collected, explicitly released, or exits its ``with`` block
— when the last reference is dropped, the store evicts the object.  The
module-level helpers :func:`clone` (a new co-owning reference),
:func:`borrow` (a non-owning proxy that keeps its owner alive), and
:func:`into_owned` (upgrade a plain/ephemeral proxy to an owning one)
implement the ownership patterns on top of any factory exposing the small
lifetime protocol (``release``/``peek``/``clone``/``into_owned``/
``add_borrow``/``drop_borrow``/``detached`` — see
:class:`repro.core.store.StoreFactory`).
"""
from __future__ import annotations

import operator
import weakref
from typing import Any, Callable, Generic, TypeVar

T = TypeVar("T")

_UNRESOLVED = object()  # sentinel: target not yet materialized


class ProxyResolveError(RuntimeError):
    """Raised when a proxy's factory fails to produce the target."""


def _do_resolve(proxy: "Proxy") -> Any:
    """Resolve ``proxy`` in place (idempotent) and return the target."""
    target = object.__getattribute__(proxy, "_proxy_target")
    if target is not _UNRESOLVED:
        return target
    factory = object.__getattribute__(proxy, "_proxy_factory")
    try:
        target = factory()
    except Exception as e:  # noqa: BLE001 - surface context, keep cause
        raise ProxyResolveError(
            f"factory {factory!r} failed to resolve proxy target: {e}"
        ) from e
    object.__setattr__(proxy, "_proxy_target", target)
    return target


class Proxy(Generic[T]):
    """Lazy transparent proxy of the object returned by ``factory``."""

    __slots__ = ("_proxy_factory", "_proxy_target", "__weakref__")

    def __init__(self, factory: Callable[[], T]) -> None:
        if not callable(factory):
            raise TypeError(f"factory must be callable, got {type(factory)}")
        object.__setattr__(self, "_proxy_factory", factory)
        object.__setattr__(self, "_proxy_target", _UNRESOLVED)

    # -- pickling: factory only, never the target -------------------------
    def __reduce__(self):
        return (Proxy, (object.__getattribute__(self, "_proxy_factory"),))

    def __reduce_ex__(self, protocol):
        return self.__reduce__()

    # -- copying: a copy of a resolved proxy stays resolved ----------------
    def __copy__(self):
        new = Proxy(object.__getattribute__(self, "_proxy_factory"))
        object.__setattr__(new, "_proxy_target",
                           object.__getattribute__(self, "_proxy_target"))
        return new

    def __deepcopy__(self, memo):
        import copy as _copy

        target = object.__getattribute__(self, "_proxy_target")
        if target is not _UNRESOLVED:
            new_target = _copy.deepcopy(target, memo)
            new = Proxy(_Resolved(new_target))
            object.__setattr__(new, "_proxy_target", new_target)
            return new
        return Proxy(_copy.deepcopy(
            object.__getattribute__(self, "_proxy_factory"), memo))

    # -- attribute protocol ------------------------------------------------
    def __getattr__(self, name: str) -> Any:
        # __slots__ attrs are found by __getattribute__; anything reaching
        # here is for the target.
        return getattr(_do_resolve(self), name)

    def __setattr__(self, name: str, value: Any) -> None:
        setattr(_do_resolve(self), name, value)

    def __delattr__(self, name: str) -> None:
        delattr(_do_resolve(self), name)

    # -- transparency: class/dir/repr/hash/eq etc. -------------------------
    @property  # type: ignore[misc]
    def __class__(self):  # noqa: D105
        return type(_do_resolve(self))

    def __dir__(self):
        return dir(_do_resolve(self))

    def __repr__(self) -> str:
        return repr(_do_resolve(self))

    def __str__(self) -> str:
        return str(_do_resolve(self))

    def __format__(self, spec: str) -> str:
        return format(_do_resolve(self), spec)

    def __hash__(self) -> int:
        return hash(_do_resolve(self))

    def __bool__(self) -> bool:
        return bool(_do_resolve(self))

    def __len__(self) -> int:
        return len(_do_resolve(self))

    def __iter__(self):
        return iter(_do_resolve(self))

    def __next__(self):
        return next(_do_resolve(self))

    def __reversed__(self):
        return reversed(_do_resolve(self))

    def __contains__(self, item) -> bool:
        return item in _do_resolve(self)

    def __getitem__(self, key):
        return _do_resolve(self)[key]

    def __setitem__(self, key, value) -> None:
        _do_resolve(self)[key] = value

    def __delitem__(self, key) -> None:
        del _do_resolve(self)[key]

    def __call__(self, *args, **kwargs):
        return _do_resolve(self)(*args, **kwargs)

    def __enter__(self):
        return _do_resolve(self).__enter__()

    def __exit__(self, *exc):
        return _do_resolve(self).__exit__(*exc)

    def __index__(self) -> int:
        return operator.index(_do_resolve(self))

    def __int__(self) -> int:
        return int(_do_resolve(self))

    def __float__(self) -> float:
        return float(_do_resolve(self))

    def __complex__(self) -> complex:
        return complex(_do_resolve(self))

    def __bytes__(self) -> bytes:
        return bytes(_do_resolve(self))

    # numpy/jax interop: let np.asarray(proxy) etc. see the target
    def __array__(self, *args, **kwargs):
        import numpy as np

        return np.asarray(_do_resolve(self), *args, **kwargs)

    @property
    def __array_interface__(self):
        return _do_resolve(self).__array_interface__

    def __jax_array__(self):
        import jax.numpy as jnp

        return jnp.asarray(_do_resolve(self))


def _forward_rbinary(dunder: str):
    def fwd(self, other):
        target = _do_resolve(self)
        meth = getattr(target, dunder, None)
        if meth is not None:
            return meth(_unwrap(other))
        return NotImplemented

    return fwd


def _forward_unary(dunder: str):
    def fwd(self):
        return getattr(_do_resolve(self), dunder)()

    return fwd


def _unwrap(obj):
    if issubclass(type(obj), Proxy):   # real-type check; __class__ lies
        return _do_resolve(obj)
    return obj


_BINARY = {
    "__add__": "add", "__sub__": "sub", "__mul__": "mul",
    "__truediv__": "truediv", "__floordiv__": "floordiv", "__mod__": "mod",
    "__pow__": "pow", "__matmul__": "matmul", "__and__": "and_",
    "__or__": "or_", "__xor__": "xor", "__lshift__": "lshift",
    "__rshift__": "rshift", "__lt__": "lt", "__le__": "le", "__eq__": "eq",
    "__ne__": "ne", "__gt__": "gt", "__ge__": "ge", "__divmod__": None,
}
for dunder, opname in _BINARY.items():
    if opname is not None:
        op = getattr(operator, opname)

        def _mk(op):
            def fwd(self, other):
                return op(_do_resolve(self), _unwrap(other))
            return fwd

        setattr(Proxy, dunder, _mk(op))
    else:
        def _mkd(dunder):
            def fwd(self, other):
                return getattr(_do_resolve(self), dunder)(_unwrap(other))
            return fwd

        setattr(Proxy, dunder, _mkd(dunder))

for dunder in (
    "__radd__", "__rsub__", "__rmul__", "__rtruediv__", "__rfloordiv__",
    "__rmod__", "__rpow__", "__rmatmul__", "__rand__", "__ror__", "__rxor__",
    "__rlshift__", "__rrshift__", "__rdivmod__",
):
    setattr(Proxy, dunder, _forward_rbinary(dunder))

for dunder in ("__neg__", "__pos__", "__abs__", "__invert__", "__round__",
               "__trunc__", "__floor__", "__ceil__"):
    setattr(Proxy, dunder, _forward_unary(dunder))


# ---------------------------------------------------------------------------
# Ownership: OwnedProxy + borrow/clone/into_owned (arXiv:2407.01764 patterns)
# ---------------------------------------------------------------------------

class _Resolved:
    """Trivial factory wrapping an already-materialized value (deepcopies of
    resolved proxies; pickles the value itself, not a reference)."""

    __slots__ = ("value",)

    def __init__(self, value: Any) -> None:
        self.value = value

    def __call__(self) -> Any:
        return self.value


def _quiet_release(release_fn: Callable[[], Any]) -> None:
    """GC-time release: the store/server may already be gone — a leaked
    reference is bounded by its lease, so never raise out of a finalizer.
    Sanitizer detections (double-decref from a finalizer racing an
    explicit release, use-after-evict) DO propagate: hiding them defeats
    the point of running sanitized."""
    try:
        release_fn()
    except Exception as exc:  # noqa: BLE001 - GC context, lease backstop
        if getattr(exc, "diagnostic", None) is not None:
            raise


class OwnedProxy(Proxy[T]):
    """A transparent proxy that OWNS one reference to its target's storage.

    The reference is dropped (store ``decref``; at zero the key is evicted)
    when the proxy is garbage-collected, explicitly :func:`release`-d, or
    exits its ``with`` block.  Unlike a plain ``evict=True`` proxy, resolving
    an OwnedProxy does NOT consume the object: it stays available until the
    last owner drops it.

    Pickling an OwnedProxy acquires a reference for the communicated copy
    (clone-on-pickle), so every deserialized consumer owns its own lifetime.
    Note the caveat: unpickling one serialized blob N times yields N proxies
    but only one acquired reference — for broadcast fan-out create one clone
    (or sibling ``evict=True`` proxy) per consumer, and put a TTL lease on
    the key as a crash backstop.

    ``with owned as p:`` manages the *lifetime* (release on exit); it
    deliberately shadows the transparent forwarding of ``__enter__`` to a
    context-manager target.

    GC-time release is best-effort and skipped at interpreter exit; TTL
    leases (``Store.lease`` / ``owned_proxy(ttl=...)``) bound any leak.
    """

    __slots__ = ("_proxy_finalizer",)

    def __init__(self, factory: Callable[[], T]) -> None:
        super().__init__(factory)
        release_fn = getattr(factory, "release", None)
        fin = None
        if release_fn is not None:
            fin = weakref.finalize(self, _quiet_release, release_fn)
            # do not decref over the network during interpreter teardown;
            # the server-side lease handles refs the process dies holding
            fin.atexit = False
        object.__setattr__(self, "_proxy_finalizer", fin)

    def __reduce__(self):
        return (OwnedProxy,
                (object.__getattribute__(self, "_proxy_factory"),))

    def __copy__(self):
        return clone(self)

    def __deepcopy__(self, memo):
        import copy as _copy

        new = clone(self)
        target = object.__getattribute__(self, "_proxy_target")
        if target is not _UNRESOLVED:
            # an independent target, not a shared view of the store cache
            object.__setattr__(new, "_proxy_target",
                               _copy.deepcopy(target, memo))
        return new

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        release(self)
        return False


def release(proxy: Proxy) -> None:
    """Drop an :class:`OwnedProxy`'s reference now (idempotent).

    Raises ``RuntimeError`` if borrowed proxies created from it are still
    alive.  After release the proxy must not be resolved or pickled.
    """
    # real-type check: isinstance() would consult __class__ and RESOLVE the
    # proxy (consuming ephemeral references) just to answer the question
    if not issubclass(type(proxy), OwnedProxy):
        return
    factory = object.__getattribute__(proxy, "_proxy_factory")
    release_fn = getattr(factory, "release", None)
    if release_fn is not None:
        # call the factory directly — it checks borrows under its own lock
        # and raises BEFORE the once-only finalizer is consumed, so a
        # racing borrow can never permanently disarm the release
        release_fn()
    fin = object.__getattribute__(proxy, "_proxy_finalizer")
    if fin is not None:
        fin.detach()   # reference dropped: disarm the GC-time release


class _Borrowed:
    """Non-owning factory of a borrowed proxy.

    Holds a STRONG reference to the owner proxy, so the owner cannot be
    garbage-collected (and therefore cannot drop the last reference) while
    any borrow is alive; explicit ``release`` of the owner raises instead.
    Resolution never consumes a reference.  Pickling detaches: the
    communicated copy becomes a plain non-owning factory, valid for as long
    as some reference holder keeps the key alive.
    """

    __slots__ = ("owner", "factory")

    def __init__(self, owner: Proxy, factory: Any) -> None:
        self.owner = owner
        self.factory = factory
        factory.add_borrow()

    def __call__(self) -> Any:
        if is_resolved(self.owner):
            return object.__getattribute__(self.owner, "_proxy_target")
        return self.factory.peek()

    def __del__(self) -> None:
        try:
            self.factory.drop_borrow()
        except Exception:  # noqa: BLE001 - GC context
            pass

    def __reduce__(self):
        return (_detached_factory, (self.factory.detached(),))


def _detached_factory(factory: Any) -> Any:
    return factory


def borrow(proxy: Proxy) -> Proxy:
    """A non-owning proxy to the same target; keeps ``proxy``'s owner alive
    for the borrow's lifetime and never consumes a reference."""
    factory = object.__getattribute__(proxy, "_proxy_factory")
    if not (hasattr(factory, "peek") and hasattr(factory, "add_borrow")):
        raise TypeError(
            f"factory {type(factory).__name__} does not support borrowing")
    return Proxy(_Borrowed(proxy, factory))


def clone(proxy: Proxy) -> "OwnedProxy":
    """A new co-owning :class:`OwnedProxy`: acquires one more reference, so
    the target outlives whichever owner drops last."""
    factory = object.__getattribute__(proxy, "_proxy_factory")
    clone_fn = getattr(factory, "clone", None)
    if clone_fn is None:
        raise TypeError(
            f"factory {type(factory).__name__} does not support cloning")
    return OwnedProxy(clone_fn())


def into_owned(proxy: Proxy) -> "OwnedProxy":
    """Upgrade a plain or ``evict=True`` proxy into an :class:`OwnedProxy`.

    An unconsumed ``evict=True`` proxy *moves* its pending reference into
    the owner (the original proxy resolves without consuming afterwards); a
    plain proxy acquires a fresh reference.
    """
    # real-type check — isinstance would resolve the proxy via __class__
    if issubclass(type(proxy), OwnedProxy):
        return proxy
    factory = object.__getattribute__(proxy, "_proxy_factory")
    fn = getattr(factory, "into_owned", None)
    if fn is None:
        raise TypeError(
            f"factory {type(factory).__name__} does not support ownership")
    return OwnedProxy(fn())


# ---------------------------------------------------------------------------
# Module-level utilities (mirroring proxystore.proxy's API)
# ---------------------------------------------------------------------------

def is_resolved(proxy: Proxy) -> bool:
    """True if ``proxy``'s target has been materialized."""
    return object.__getattribute__(proxy, "_proxy_target") is not _UNRESOLVED


def resolve(proxy: Proxy) -> None:
    """Force resolution of ``proxy`` (no-op if already resolved)."""
    _do_resolve(proxy)


def extract(proxy: Proxy):
    """Return the target object of ``proxy``, resolving if necessary."""
    return _do_resolve(proxy)


def get_factory(proxy: Proxy) -> Callable[[], Any]:
    """Return the factory embedded in ``proxy``."""
    return object.__getattribute__(proxy, "_proxy_factory")


def is_proxy(obj: Any) -> bool:
    """True if ``obj`` is a Proxy (or OwnedProxy) — real-type check, immune
    to the ``__class__`` lie."""
    return Proxy in type(obj).__mro__
