"""The Connector protocol (paper §3.4).

A Connector is a low-level interface to a *mediated channel*: it moves opaque
byte payloads identified by keys.  Four primary operations — ``put``, ``get``,
``exists``, ``evict`` — plus batch variants and lifecycle hooks.

Object-lifecycle extension (the ownership subsystem, following the proxy
ownership patterns of arXiv:2407.01764): ``incref``/``decref``/``refcount``
manage per-key reference counts (decref to zero evicts, exactly once) and
``touch`` sets TTL leases bounding leaks from crashed reference holders.
KV-backed connectors forward these to their server, where count mutations
are atomic on the server's event loop — safe across processes and sites.
:class:`BaseConnector` supplies a *process-local* fallback table so every
connector supports the API; for purely local connectors (file, memory, shm)
the counts protect same-process consumers only, which is documented
behavior, not a bug: cross-process ownership needs a KV-backed channel.

``put`` accepts ``bytes | Frame | Sequence[memoryview]`` (see
:mod:`repro.core.serialize`): scatter-gather-capable channels write the
segments directly, others fall back to a single ``join_frame`` copy.  ``get``
may return any bytes-like object (``bytes`` or a zero-copy ``memoryview``,
e.g. a slice of a mapped shared-memory arena) suitable for ``deserialize``.
Mapped views stay *valid* until the connector closes, but their *contents*
are only stable until the key is evicted — consumers that hold
deserialized zero-copy arrays across an eviction must pin the key with a
reference (see the lifecycle extension below) or copy.

Futures + streams extension (communicate data BEFORE it exists, following
the distributed-future and streaming proxy patterns of arXiv:2407.01764):

* ``reserve()`` mints a key with no data behind it; ``put_to(key, blob)``
  later lands the payload under that exact key.  A proxy carrying a
  reserved key is valid before the data exists — its resolve blocks in
  ``wait``.
* ``wait(key, timeout)`` blocks until the key's payload exists and returns
  it (``TimeoutError`` if no producer shows up).  KV-backed connectors
  park inside the server (``wait`` op — zero polling, released by the
  producer's ``put2`` even from another connection or a peered site);
  :class:`BaseConnector` supplies a channel-scoped in-process fallback: a
  condition variable notified by same-process producers via ``announce``,
  with a short existence poll so cross-process file-backed producers are
  also seen.
* ``stream_append`` / ``stream_next`` / ``stream_fetch`` /
  ``stream_close``: per-topic ordered streams with an end-of-stream
  marker.  Items are refcount-integrated — consuming decrefs, so each
  item is evicted exactly once after its consumer took it.  KV-backed
  connectors forward to their server's stream ops (``s_append`` etc.);
  the fallback keeps a channel-scoped topic table and stores items
  through the connector's own ``put``.
* Pub/sub group extension (the broker-backed stream plane —
  :mod:`repro.stream` rides these): ``stream_subscribe`` /
  ``stream_unsubscribe`` attach named consumer groups with independent
  cursors and optional server-side metadata filters; ``stream_take`` /
  ``stream_take_batch`` deliver events per group (unacked until
  ``stream_ack`` — the payload is retained with one reference per
  matching group and evicted after the LAST group acks, so bytes cross
  the data plane once regardless of fanout); ``stream_requeue`` returns
  delivered-but-unprocessed events to the group; ``stream_limit``
  installs credit-based producer backpressure.  ``stream_append`` takes
  the event's metadata map and a backpressure timeout.  KV-backed
  connectors forward to the server group ops (``s_sub``/``s_next2``/…);
  the fallback implements the same semantics on the channel-scoped
  topic table.

Keys are plain tuples of msgpack-serializable scalars so they can ride inside
factories across process and site boundaries.

Connectors must additionally be *reconstructible from config*: ``config()``
returns kwargs such that ``type(conn)(**conn.config())`` connects to the same
channel from any process.  This is what lets a proxy resolved on a remote
process re-materialize its Store (paper §3.5's registry behavior).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, NamedTuple, Protocol, Sequence, runtime_checkable

from repro.stream.broker import BrokerEvent

Key = tuple  # (str | int, ...)

# process-local lifecycle tables for connectors without a server to hold
# counts, keyed by CHANNEL identity (not connector instance): a connector
# rebuilt from config in the same process must see the same counts
_LIFETIME_TABLES: dict[tuple, dict] = {}
_LIFETIME_LOCK = threading.Lock()

# channel-scoped futures/stream state for connectors without a server:
# condition variable (producer announce -> consumer wake) + topic tables
_CHANNEL_TABLES: dict[tuple, dict] = {}
_CHANNEL_LOCK = threading.Lock()

_WAIT_POLL = 0.05   # fallback existence poll (cross-process producers)


class StreamItem(NamedTuple):
    """One consumed stream element.

    ``end=True`` marks end-of-stream (``data`` is None); ``available`` is
    the producer's appended count at serve time — the consumer uses it to
    batch-prefetch the already-ready tail."""

    seq: int
    data: Any            # bytes-like | None
    available: int
    end: bool


@runtime_checkable
class Connector(Protocol):
    """Byte-level mediated-channel interface."""

    def put(self, blob) -> Key:
        """Store ``blob`` (bytes | Frame | segment sequence); return a key."""
        ...

    def get(self, key: Key):
        """Return a bytes-like payload for ``key`` or None if absent."""
        ...

    def exists(self, key: Key) -> bool:
        ...

    def evict(self, key: Key) -> None:
        ...

    def config(self) -> dict[str, Any]:
        """Kwargs to reconstruct an equivalent connector anywhere."""
        ...

    def close(self) -> None:
        ...


class BaseConnector:
    """Shared batch defaults, lifecycle fallback + context-manager plumbing."""

    # True when ``get`` returns views of memory the CHANNEL still owns and
    # may recycle after the key's eviction (the shm arena).  The Store's
    # lifecycle-bound resolves materialize (deep-copy) such results before
    # dropping their reference; connectors whose gets return fresh/immutable
    # buffers (file, kv, memory) keep zero-copy semantics all the way.
    borrows_get = False

    def put_batch(self, blobs: Sequence[bytes]) -> list[Key]:
        return [self.put(b) for b in blobs]

    def get_batch(self, keys: Sequence[Key]) -> list[bytes | None]:
        return [self.get(k) for k in keys]

    def exists_batch(self, keys: Sequence[Key]) -> list[bool]:
        return [self.exists(k) for k in keys]

    def evict_batch(self, keys: Sequence[Key]) -> None:
        for k in keys:
            self.evict(k)

    # -- lifecycle: refcounts + leases ---------------------------------------
    # Process-local fallback (see module docstring).  KV-backed connectors
    # override these with single-exchange server ops.
    def _lifetime_scope(self):
        """Hashable identity of the CHANNEL this connector talks to;
        connectors reconstructible from config should override so rebuilt
        instances share one count table (default: per-instance)."""
        return id(self)

    def _lifetime_state(self):
        scope = (type(self).__name__, self._lifetime_scope())
        with _LIFETIME_LOCK:
            state = _LIFETIME_TABLES.get(scope)
            if state is None:
                state = _LIFETIME_TABLES[scope] = {
                    "lock": threading.Lock(), "refs": {}, "leases": {},
                }
            return state

    def _drop_lifetime_state(self) -> None:
        """Forget this channel's fallback count table (call from close():
        like the channel's data, counts don't outlive the channel)."""
        scope = (type(self).__name__, self._lifetime_scope())
        with _LIFETIME_LOCK:
            _LIFETIME_TABLES.pop(scope, None)

    def _forget_lifetime(self, key: Key) -> None:
        """Drop fallback refs/leases for one explicitly evicted key, so
        lifecycle state dies with the data (mirrors the server-side
        ``_evict``).  No-op when this channel has no fallback table."""
        scope = (type(self).__name__, self._lifetime_scope())
        with _LIFETIME_LOCK:
            state = _LIFETIME_TABLES.get(scope)
        if state is None:
            return
        with state["lock"]:
            state["refs"].pop(tuple(key), None)
            state["leases"].pop(tuple(key), None)

    def _sweep_local(self, state) -> None:
        # monotonic: a wall-clock (NTP) step must not reap live leases
        now = time.monotonic()
        expired = [k for k, t in state["leases"].items() if t <= now]
        for k in expired:
            state["leases"].pop(k, None)
            state["refs"].pop(k, None)
            self.evict(k)

    def incref(self, key: Key, n: int = 1) -> int:
        state = self._lifetime_state()
        with state["lock"]:
            self._sweep_local(state)
            key = tuple(key)
            count = state["refs"].get(key, 0) + n
            state["refs"][key] = count
            return count

    def decref(self, key: Key, n: int = 1) -> int:
        state = self._lifetime_state()
        with state["lock"]:
            self._sweep_local(state)
            key = tuple(key)
            count = state["refs"].get(key)
            if count is None:
                # no entry HERE ≠ no references: this table is process-
                # local, so the count usually lives with the creating
                # process — never evict data other consumers may need
                # (server-backed connectors, whose counts are
                # authoritative, treat this case as the legacy hard evict)
                return 0
            count -= n
            if count > 0:
                state["refs"][key] = count
                return count
            state["refs"].pop(key, None)
            state["leases"].pop(key, None)
        self.evict(key)            # count hit zero: evict exactly once
        return 0

    def refcount(self, key: Key) -> int:
        state = self._lifetime_state()
        with state["lock"]:
            self._sweep_local(state)
            return state["refs"].get(tuple(key), 0)

    def touch(self, key: Key, ttl: float | None) -> bool:
        state = self._lifetime_state()
        with state["lock"]:
            self._sweep_local(state)
            key = tuple(key)
            if ttl is None or ttl <= 0:
                state["leases"].pop(key, None)
            else:
                state["leases"][key] = time.monotonic() + ttl
        return self.exists(key)

    def sweep_leases(self) -> int:
        """Expire overdue fallback leases NOW (evicting their keys);
        returns the number reclaimed.  Normally expiry rides every
        lifecycle op lazily — this is the explicit pressure-time hook
        (e.g. a KV-block pool over budget reclaiming blocks whose holder
        crashed).  Server-backed connectors, whose servers expire leases
        themselves, inherit this as a local no-op."""
        state = self._lifetime_state()
        with state["lock"]:
            before = len(state["leases"])
            self._sweep_local(state)
            return before - len(state["leases"])

    def incref_batch(self, keys: Sequence[Key], n: int = 1) -> list[int]:
        return [self.incref(k, n) for k in keys]

    def decref_batch(self, keys: Sequence[Key], n: int = 1) -> list[int]:
        return [self.decref(k, n) for k in keys]

    def touch_batch(self, keys: Sequence[Key], ttl: float | None) -> None:
        for k in keys:
            self.touch(k, ttl)

    # True when stream topics are location-addressed: they live on the
    # PRODUCING site's server (a socket node id, a PS-endpoint uuid) and a
    # consumer elsewhere passes that id as ``location``.  False means the
    # channel has exactly one stream home (this process, one KV server, a
    # topic's fabric shard) and a ``location`` argument would silently
    # subscribe to a topic nothing ever produces — the Store layer raises
    # instead.
    supports_location = False

    # -- block reservation (arena-backed channels only) ----------------------
    # True when the channel can hand out writable in-place payload views
    # (``reserve_block``/``commit_block``); consumers without it fall back
    # to ordinary serialized puts.
    supports_blocks = False

    def reserve_block(self, nbytes: int):
        raise NotImplementedError(
            f"{type(self).__name__} does not support block reservation")

    def commit_block(self, key: Key) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not support block reservation")

    # -- futures: reserved keys + blocking wait ------------------------------
    # Channel-scoped in-process fallback: a condition variable notified by
    # same-process producers (``announce``), plus a short existence poll so
    # producers on OTHER processes sharing the channel (e.g. a file store)
    # are seen too.  KV-backed connectors override ``wait`` with the
    # server-side parked op — no polling at all.
    def _channel_state(self) -> dict:
        scope = (type(self).__name__, self._lifetime_scope())
        with _CHANNEL_LOCK:
            state = _CHANNEL_TABLES.get(scope)
            if state is None:
                state = _CHANNEL_TABLES[scope] = {
                    "cond": threading.Condition(), "streams": {},
                }
            return state

    def _drop_channel_state(self) -> None:
        scope = (type(self).__name__, self._lifetime_scope())
        with _CHANNEL_LOCK:
            _CHANNEL_TABLES.pop(scope, None)

    def reserve(self) -> Key:
        """Mint a key with no data behind it yet (``put_to`` lands the
        payload later; consumers block in ``wait`` meanwhile)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support reserved keys")

    def put_to(self, key: Key, blob) -> None:
        """Store ``blob`` under a key minted by :meth:`reserve`."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support reserved keys")

    def announce(self, key: Key) -> None:
        """Wake same-process consumers blocked in the fallback ``wait``
        (server-backed channels wake waiters server-side; their override
        of ``wait`` makes this a harmless no-op)."""
        state = self._channel_state()
        with state["cond"]:
            state["cond"].notify_all()

    def wait(self, key: Key, timeout: float = 60.0):
        """Block until ``key``'s payload exists; returns it.  Raises
        ``TimeoutError`` if no producer lands the key in time."""
        key = tuple(key)
        deadline = time.monotonic() + float(timeout)
        state = self._channel_state()
        while True:
            if self.exists(key):
                blob = self.get(key)
                if blob is not None:
                    return blob
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(f"wait timed out on {key}")
            with state["cond"]:
                state["cond"].wait(min(remaining, _WAIT_POLL))

    # -- streams: channel-scoped in-process fallback -------------------------
    # Topic state lives with the channel; item data rides the connector's
    # own put/get/evict, so any connector gets working same-process streams
    # for free.  Refcount-integrated like the server path: append increfs
    # the item once, consumption decrefs it (eviction at zero).
    def _stream_state(self, topic: str) -> dict:
        streams = self._channel_state()["streams"]
        st = streams.get(topic)
        if st is None:
            st = streams[topic] = {
                "count": 0, "closed": False, "keys": [],
                # pub/sub group state: name -> {queue, unacked, fn};
                # owners counts outstanding group refs per seq (the
                # backpressure "buffered" measure); meta rides filters
                "groups": {}, "meta": {}, "owners": {}, "limit": None,
                # (group, seq) -> delivery count; events delivered more
                # than max_deliveries times dead-letter to <topic>.dlq
                "deliveries": {}, "max_deliveries": None,
            }
        return st

    def stream_append(self, topic: str, blob, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        state = self._channel_state()
        deadline = None
        with state["cond"]:
            st = self._stream_state(topic)
            while (st["limit"] is not None
                   and len(st["owners"]) >= st["limit"]
                   and not st["closed"]):
                # credit-based backpressure: park until consumer acks
                # free a buffer slot (the ack path notifies this cond)
                if deadline is None:
                    deadline = time.monotonic() + (
                        timeout if timeout is not None else 60.0)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"stream {topic!r} append timed out on "
                        f"backpressure (buffer full)")
                state["cond"].wait(remaining)
            if st["closed"]:
                raise RuntimeError(f"stream {topic!r} is closed")
            seq = st["count"]
            st["count"] += 1
            m = meta or {}
            groups = st["groups"]
            matched = (None if not groups else
                       [g for g in groups.values()
                        if g["fn"] is None or g["fn"](m)])
            if meta:
                st["meta"][seq] = dict(meta)
            if matched is not None and not matched:
                # filtered out by EVERY group: the payload is never
                # stored — zero bytes enter the data plane
                st["keys"].append(None)
            else:
                key = tuple(self.put(blob))
                # legacy topic (no groups): one ref, dropped by the
                # consumer; grouped topic: one ref per matching group,
                # each dropped by that group's ack
                self.incref(key, 1 if matched is None else len(matched))
                if ttl is not None:
                    self.touch(key, ttl)     # abandoned-stream backstop
                st["keys"].append(key)
                if matched:
                    st["owners"][seq] = len(matched)
            for g in matched or []:
                g["queue"].append(seq)
            state["cond"].notify_all()
        return seq

    def stream_close(self, topic: str, location: str | None = None) -> None:
        state = self._channel_state()
        with state["cond"]:
            self._stream_state(topic)["closed"] = True
            state["cond"].notify_all()

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    location: str | None = None) -> StreamItem:
        # ``location`` addresses the topic's owning site on location-
        # addressed channels (PS-endpoints); local channels ignore it
        """Block until item ``seq`` exists (consume it) or the stream
        closes (``end=True``); ``TimeoutError`` if neither happens."""
        deadline = time.monotonic() + float(timeout)
        state = self._channel_state()
        with state["cond"]:
            while True:
                st = self._stream_state(topic)
                if st["count"] > seq:
                    key, available = st["keys"][seq], st["count"]
                    break
                if st["closed"]:
                    return StreamItem(seq, None, st["count"], True)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"stream {topic!r} item {seq} timed out")
                state["cond"].wait(remaining)
        blob = self.get(key)
        if blob is not None and self.borrows_get:
            # the decref below is the item's LAST reference: detach the
            # payload before the channel may recycle its backing memory
            blob = bytes(memoryview(blob))
        self.decref(key)                 # consumed: refcount hits zero
        return StreamItem(seq, blob, available, False)

    def stream_fetch(self, topic: str, seqs: Sequence[int],
                     location: str | None = None) -> list:
        """Consume already-available items (the prefetch path; batched on
        server-backed channels)."""
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            keys = [st["keys"][int(s)] for s in seqs]
        blobs = self.get_batch(keys)
        if self.borrows_get:
            blobs = [bytes(memoryview(b)) if b is not None else None
                     for b in blobs]
        self.decref_batch(keys)
        return blobs

    # -- pub/sub consumer groups: channel-scoped in-process fallback ---------
    # Same semantics as the server group ops (kv_tcp.StreamTable), on the
    # channel-scoped topic table: per-group cursors + acks, payloads held
    # with one connector refcount per matching group and evicted by the
    # last group's ack, filters evaluated at append time.
    def _drop_stream_owner(self, st: dict, seq: int) -> None:
        n = st["owners"].get(seq)
        if n is None:
            return
        if n <= 1:
            st["owners"].pop(seq, None)
            st["meta"].pop(seq, None)
        else:
            st["owners"][seq] = n - 1
        key = st["keys"][seq]
        if key is not None:
            self.decref(key)             # refcount zero on last drop: evict

    def stream_subscribe(self, topic: str, group: str, start: str = "new",
                         filter: dict | None = None,  # noqa: A002
                         location: str | None = None) -> dict:
        from repro.stream.filters import compile_filter

        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            g = st["groups"].get(group)
            created = g is None
            if created:
                fn = compile_filter(filter) if filter else None
                g = {"queue": collections.deque(), "unacked": set(),
                     "fn": fn}
                st["groups"][group] = g
                if start == "begin":
                    for seq in range(st["count"]):
                        key = st["keys"][seq]
                        if key is None or not self.exists(key):
                            continue     # filtered-at-append or consumed
                        if fn is not None and \
                                not fn(st["meta"].get(seq) or {}):
                            continue
                        g["queue"].append(seq)
                        if st["owners"].get(seq):
                            st["owners"][seq] += 1
                            self.incref(key)
                        else:
                            # adopt the legacy single reference
                            st["owners"][seq] = 1
                state["cond"].notify_all()
            return {"created": created, "queued": len(g["queue"]),
                    "count": st["count"], "closed": st["closed"]}

    def stream_unsubscribe(self, topic: str, group: str,
                           location: str | None = None) -> None:
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            g = st["groups"].pop(group, None)
            if g is None:
                return
            for seq in (*g["queue"], *g["unacked"]):
                self._drop_stream_owner(st, seq)
            d = st["deliveries"]
            for k in [k for k in d if k[0] == group]:
                d.pop(k, None)
            state["cond"].notify_all()

    def _stream_pop(self, st: dict, group: str) -> tuple | None:
        g = st["groups"].get(group)
        if g is None:
            raise KeyError(f"no consumer group {group!r}")
        if not g["queue"]:
            return None
        seq = g["queue"].popleft()
        g["unacked"].add(seq)
        d = st["deliveries"]
        d[(group, seq)] = d.get((group, seq), 0) + 1
        return seq, st["keys"][seq], dict(st["meta"].get(seq) or {})

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True,
                    location: str | None = None) -> BrokerEvent:
        deadline = time.monotonic() + float(timeout)
        state = self._channel_state()
        with state["cond"]:
            while True:
                st = self._stream_state(topic)
                popped = self._stream_pop(st, group)
                if popped is not None:
                    seq, key, meta = popped
                    break
                if st["closed"]:
                    return BrokerEvent(-1, None, {}, end=True)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"stream {topic!r} group {group!r} timed out")
                state["cond"].wait(remaining)
        blob = self.get(key) if (payload and key is not None) else None
        if blob is not None and self.borrows_get:
            blob = bytes(memoryview(blob))   # the ack may recycle memory
        return BrokerEvent(seq, blob, meta)

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True,
                          location: str | None = None) -> list[BrokerEvent]:
        taken: list[tuple] = []
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            while len(taken) < n:
                popped = self._stream_pop(st, group)
                if popped is None:
                    break
                taken.append(popped)
        if not payload:
            return [BrokerEvent(seq, None, meta) for seq, _, meta in taken]
        blobs = self.get_batch([key for _, key, _ in taken])
        if self.borrows_get:
            blobs = [bytes(memoryview(b)) if b is not None else None
                     for b in blobs]
        return [BrokerEvent(seq, blob, meta)
                for (seq, _, meta), blob in zip(taken, blobs)]

    def stream_ack(self, topic: str, group: str, seqs,
                   location: str | None = None) -> int:
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            g = st["groups"].get(group)
            if g is None:
                return 0
            acked = {int(s) for s in seqs} & g["unacked"]
            g["unacked"] -= acked
            for seq in sorted(acked):
                st["deliveries"].pop((group, seq), None)
                self._drop_stream_owner(st, seq)
            if acked:
                state["cond"].notify_all()   # acks free producer credits
            return len(acked)

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None,
                       location: str | None = None) -> int:
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            g = st["groups"].get(group)
            if g is None:
                return 0
            claimed = {int(s) for s in seqs} & g["unacked"]
            if not claimed:
                return 0
            limit = st["max_deliveries"]
            dead = ({s for s in claimed
                     if st["deliveries"].get((group, s), 0) >= limit}
                    if limit else set())
            back = claimed - dead
            g["unacked"] -= claimed
            if back:
                g["queue"] = collections.deque(
                    sorted(back | set(g["queue"])))
            for seq in sorted(dead):
                self._dead_letter_local(st, topic, group, seq, reason)
            state["cond"].notify_all()
            return len(back)

    def _dead_letter_local(self, st: dict, topic: str, group: str,
                           seq: int, reason: str | None) -> None:
        """Move a poison event to ``<topic>.dlq`` (same channel, same
        payload key — one extra reference) with failure metadata, then
        release the group's claim on the original."""
        from repro.core.kv_tcp import dlq_topic

        deliveries = st["deliveries"].pop((group, seq), 0)
        dst = self._stream_state(dlq_topic(topic))
        if not dst["closed"]:
            dseq = dst["count"]
            dst["count"] += 1
            meta = dict(st["meta"].get(seq) or {})
            meta["dlq"] = {"topic": topic, "group": group, "seq": seq,
                           "deliveries": deliveries, "reason": reason}
            dst["meta"][dseq] = meta
            key = st["keys"][seq]
            matched = (None if not dst["groups"] else
                       [g2 for g2 in dst["groups"].values()
                        if g2["fn"] is None or g2["fn"](meta)])
            if key is None or (matched is not None and not matched):
                dst["keys"].append(None)
            else:
                self.incref(key, 1 if matched is None else len(matched))
                dst["keys"].append(key)
                if matched:
                    dst["owners"][dseq] = len(matched)
            for g2 in matched or []:
                g2["queue"].append(dseq)
        self._drop_stream_owner(st, seq)

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None,
                     location: str | None = None) -> None:
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            st["limit"] = int(limit) if limit else None
            if max_deliveries is not None:
                st["max_deliveries"] = (int(max_deliveries)
                                        if max_deliveries else None)
            state["cond"].notify_all()

    def stream_stat(self, topic: str,
                    location: str | None = None) -> dict:
        state = self._channel_state()
        with state["cond"]:
            st = self._stream_state(topic)
            out: dict = {"count": st["count"], "closed": st["closed"]}
            if st["groups"]:
                out["groups"] = {name: {"queued": len(g["queue"]),
                                        "unacked": len(g["unacked"])}
                                 for name, g in st["groups"].items()}
                out["buffered"] = len(st["owners"])
                if st["limit"] is not None:
                    out["limit"] = st["limit"]
                if st["max_deliveries"]:
                    out["max_deliveries"] = st["max_deliveries"]
            return out

    def close(self) -> None:
        self._drop_lifetime_state()
        self._drop_channel_state()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- config round-trip --------------------------------------------------
    def config(self) -> dict[str, Any]:  # pragma: no cover - abstract-ish
        raise NotImplementedError

    @classmethod
    def from_config(cls, config: dict[str, Any]):
        return cls(**config)


def group_indices(keys: Sequence[Key], field: int) -> dict[Any, list[int]]:
    """Bucket key indices by one key field — the shared scatter/gather step
    of batch ops that issue one exchange per owning node/endpoint/child."""
    groups: dict[Any, list[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k[field], []).append(i)
    return groups


def import_path(cls: type) -> str:
    return f"{cls.__module__}:{cls.__qualname__}"


def resolve_import_path(path: str) -> type:
    import importlib

    mod, _, qual = path.partition(":")
    obj: Any = importlib.import_module(mod)
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj
