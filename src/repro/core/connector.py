"""The Connector protocol (paper §3.4).

A Connector is a low-level interface to a *mediated channel*: it moves opaque
byte payloads identified by keys.  Four primary operations — ``put``, ``get``,
``exists``, ``evict`` — plus batch variants and lifecycle hooks.

``put`` accepts ``bytes | Frame | Sequence[memoryview]`` (see
:mod:`repro.core.serialize`): scatter-gather-capable channels write the
segments directly, others fall back to a single ``join_frame`` copy.  ``get``
may return any bytes-like object (``bytes`` or a zero-copy ``memoryview``,
e.g. a mapped shared-memory segment) suitable for ``deserialize``.

Keys are plain tuples of msgpack-serializable scalars so they can ride inside
factories across process and site boundaries.

Connectors must additionally be *reconstructible from config*: ``config()``
returns kwargs such that ``type(conn)(**conn.config())`` connects to the same
channel from any process.  This is what lets a proxy resolved on a remote
process re-materialize its Store (paper §3.5's registry behavior).
"""
from __future__ import annotations

from typing import Any, Protocol, Sequence, runtime_checkable

Key = tuple  # (str | int, ...)


@runtime_checkable
class Connector(Protocol):
    """Byte-level mediated-channel interface."""

    def put(self, blob) -> Key:
        """Store ``blob`` (bytes | Frame | segment sequence); return a key."""
        ...

    def get(self, key: Key):
        """Return a bytes-like payload for ``key`` or None if absent."""
        ...

    def exists(self, key: Key) -> bool:
        ...

    def evict(self, key: Key) -> None:
        ...

    def config(self) -> dict[str, Any]:
        """Kwargs to reconstruct an equivalent connector anywhere."""
        ...

    def close(self) -> None:
        ...


class BaseConnector:
    """Shared batch defaults + context-manager plumbing."""

    def put_batch(self, blobs: Sequence[bytes]) -> list[Key]:
        return [self.put(b) for b in blobs]

    def get_batch(self, keys: Sequence[Key]) -> list[bytes | None]:
        return [self.get(k) for k in keys]

    def exists_batch(self, keys: Sequence[Key]) -> list[bool]:
        return [self.exists(k) for k in keys]

    def evict_batch(self, keys: Sequence[Key]) -> None:
        for k in keys:
            self.evict(k)

    def close(self) -> None:  # pragma: no cover - default no-op
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- config round-trip --------------------------------------------------
    def config(self) -> dict[str, Any]:  # pragma: no cover - abstract-ish
        raise NotImplementedError

    @classmethod
    def from_config(cls, config: dict[str, Any]):
        return cls(**config)


def group_indices(keys: Sequence[Key], field: int) -> dict[Any, list[int]]:
    """Bucket key indices by one key field — the shared scatter/gather step
    of batch ops that issue one exchange per owning node/endpoint/child."""
    groups: dict[Any, list[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k[field], []).append(i)
    return groups


def import_path(cls: type) -> str:
    return f"{cls.__module__}:{cls.__qualname__}"


def resolve_import_path(path: str) -> type:
    import importlib

    mod, _, qual = path.partition(":")
    obj: Any = importlib.import_module(mod)
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj
