"""Slab-arena shared-memory data plane (the §4.1.3 intra-node fast path).

The original shm connector paid five syscalls and two filesystem ops per
object: every ``put`` created a fresh POSIX segment (``shm_open`` +
``ftruncate`` + ``mmap``) and published it through a JSON sidecar write +
rename; every ``get`` re-opened and re-mapped the segment.  That made a
10 KB put cost milliseconds when the serializer costs microseconds — the
opposite of the paper's claim that proxies make intra-node object passing
cost what the hardware costs.

This module replaces that design with a small number of large, pre-created
shared-memory **arenas**:

* each arena is one POSIX segment holding a fixed header, a slot table and
  a slab data region;
* allocation is a **single-writer slab allocator**: only the arena's owner
  process allocates (size-classed power-of-two chunks, per-class free
  lists, bump-pointer carving), so no cross-process lock exists on the hot
  path;
* publication is an **atomic header store**: the producer memcpys the
  payload into its slot, fills the slot entry, and flips the slot's state
  byte to COMMITTED last — that one byte is the publication point
  (replacing the sidecar write + rename entirely);
* consumers address objects by ``(arena, slot, generation)`` embedded in
  the key, so a ``get`` is: one cached ``mmap`` attach per *arena* (not
  per object), one slot-entry read, one zero-copy ``memoryview`` slice;
* cross-process eviction is an atomic state store too: a non-owner flips
  the slot to FREE_REQUESTED and the owner lazily reclaims the chunk on
  its next allocation pressure (generation bump keeps stale keys dead);
* arena exhaustion grows the pool: a fresh arena is created, and objects
  larger than half an arena get a dedicated overflow arena sized to fit.

Memory-ordering note: the commit protocol relies on the payload and slot
fields being visible before the state byte flips.  CPython byte stores
into a shared mapping are plain stores; on x86-64 (TSO) stores from one
thread are observed in order, and the interpreter's own synchronization
inserts barriers far more often than once per put.  The consumer-side
check order (state, then generation, then bounds) mirrors this.

Consumer view lifetime rule: a memoryview returned by :meth:`Arena.read`
aliases the shared mapping.  It stays *valid* (the mapping is kept alive
even past ``close`` while views are exported) but its *contents* are only
stable until the slot is evicted — after that the owner may recycle the
chunk.  Pin objects with the refcount/lease API if consumers outlive the
producer's eviction decisions.
"""
from __future__ import annotations

import inspect
import os
import struct
import sys
import threading
import uuid
from multiprocessing import shared_memory
from pathlib import Path
from typing import Any, Iterator

from repro.analysis import sanitize as _san
from repro.core.serialize import copy_segments_into

# -- slot states (one byte; the publication point) --------------------------
# The state byte has exactly ONE writer — the arena's owner.  Non-owner
# eviction goes through the generation-tagged ``freq`` (free-request) field
# instead: stomping the state byte from another process could race the
# owner recycling the slot and kill an unrelated new object, while a stale
# gen-tagged request simply never matches.
FREE = 0            # unused / reclaimed
WRITING = 1         # allocated, payload being written (never readable)
COMMITTED = 2       # published: readable by any process

_MAGIC = b"PSAR"
_VERSION = 1

# header: magic | version u16 | nslots u32 | arena size u64 | data_off u64
#         | owner pid u32 | slots_used u32 (high-water mark for id scans)
_HEADER = struct.Struct("<4sHIQQII")
_HEADER_SPAN = 64                     # header region is padded to 64 B

# slot entry: state u8 | klass u8 | pad u16 | gen u32
#             | freq u32 (generation whose free a non-owner requested)
#             | size u64 | offset u64
#             | id 16s (uuid bytes for reserved-key lookup; zero otherwise)
_SLOT = struct.Struct("<BBHIIQQ16s")
SLOT_SIZE = _SLOT.size                # 44 B
_FREQ_OFF = 8                         # byte offset of freq within an entry
_NO_FREQ = 0xFFFFFFFF                 # freq value matching no generation

_ALIGN = 64                           # data chunks are 64-byte aligned
_MIN_KLASS = 10                       # smallest chunk: 1 KiB
DEFAULT_ARENA_SIZE = 64 * 1024 * 1024
DEFAULT_NSLOTS = 2048

NO_ID = b"\x00" * 16

_HAS_TRACK = "track" in inspect.signature(
    shared_memory.SharedMemory.__init__).parameters


def _open_segment(name: str, *, create: bool = False,
                  size: int = 0) -> shared_memory.SharedMemory:
    """Open/create a segment WITHOUT resource-tracker registration —
    arena lifetime is explicit (owner close / registry sweep)."""
    kwargs: dict[str, Any] = {"track": False} if _HAS_TRACK else {}
    if create:
        seg = shared_memory.SharedMemory(name=name, create=True,
                                         size=max(1, size), **kwargs)
    else:
        seg = shared_memory.SharedMemory(name=name, **kwargs)
    if not _HAS_TRACK:
        try:
            from multiprocessing import resource_tracker

            resource_tracker.unregister(seg._name, "shared_memory")
        except Exception:  # pragma: no cover - tracker internals vary
            pass
    return seg


def _unlink_segment(seg: shared_memory.SharedMemory) -> None:
    """Unlink, balancing tracker bookkeeping on Python < 3.13."""
    if not _HAS_TRACK:
        try:
            from multiprocessing import resource_tracker

            resource_tracker.register(seg._name, "shared_memory")
        except Exception:  # pragma: no cover
            pass
    seg.unlink()


def close_mapping(seg: shared_memory.SharedMemory) -> None:
    """Close a mapping, tolerating exported zero-copy views: the fd drops
    now, the mmap stays referenced by the views and is unmapped by the GC
    with the last of them."""
    try:
        seg.close()
    except BufferError:
        try:
            if seg._fd >= 0:
                os.close(seg._fd)
                seg._fd = -1
            seg._mmap = None
            seg._buf = None
        except Exception:  # pragma: no cover - stdlib internals shift
            pass


def size_class(nbytes: int) -> int:
    """Power-of-two size class index (chunk size ``1 << klass``)."""
    klass = max(nbytes - 1, 1).bit_length()
    return max(klass, _MIN_KLASS)


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


class Arena:
    """One mapped arena segment.

    Exactly one process — the creator — may allocate (``owner=True``); any
    process may attach, read committed slots and request frees.  All the
    allocator's bookkeeping (free lists, bump pointer, free slot stack)
    lives in the owner's private memory: the shared header only carries
    what readers need.
    """

    def __init__(self, name: str, *, create: bool = False,
                 size: int = DEFAULT_ARENA_SIZE,
                 nslots: int = DEFAULT_NSLOTS,
                 sanitize: bool = False) -> None:
        self.name = name
        self.owner = create
        self.sanitize = bool(sanitize)
        # sanitizer state: views this process exported via read() (slot ->
        # [_Export]), and the owner's freed-chunk quarantine (reuse only
        # after a strictly younger free, so stale views read poison, not a
        # silently-recycled object)
        self._exports: dict[int, list[_Export]] = {}
        self._quarantine: list[tuple[int, int, int]] = []
        self._epoch = 0
        if create:
            data_off = -(-(_HEADER_SPAN + nslots * SLOT_SIZE) // _ALIGN) \
                * _ALIGN
            total = data_off + size
            self.seg = _open_segment(name, create=True, size=total)
            self.nslots = nslots
            self.data_off = data_off
            self.size = total
            _HEADER.pack_into(self.seg.buf, 0, _MAGIC, _VERSION, nslots,
                              total, data_off, os.getpid(), 0)
            # owner-only allocator state
            self._bump = data_off
            self._free_chunks: dict[int, list[int]] = {}
            self._free_slots: list[int] = []
            self._next_slot = 0
        else:
            self.seg = _open_segment(name)
            try:
                magic, version, nslots, total, data_off, _pid, _used = \
                    _HEADER.unpack_from(self.seg.buf, 0)
            except struct.error:
                magic = None
            if magic != _MAGIC:
                close_mapping(self.seg)
                raise ValueError(f"{name} is not a PSAR arena")
            self.nslots = nslots
            self.data_off = data_off
            self.size = total

    # -- shared-header helpers ----------------------------------------------
    def _slot_off(self, slot: int) -> int:
        return _HEADER_SPAN + slot * SLOT_SIZE

    def _entry(self, slot: int) -> tuple:
        return _SLOT.unpack_from(self.seg.buf, self._slot_off(slot))

    def _write_entry(self, slot: int, state: int, klass: int, gen: int,
                     size: int, offset: int, idbytes: bytes,
                     freq: int = _NO_FREQ) -> None:
        _SLOT.pack_into(self.seg.buf, self._slot_off(slot), state, klass, 0,
                        gen, freq, size, offset, idbytes)

    def _set_state(self, slot: int, state: int) -> None:
        self.seg.buf[self._slot_off(slot)] = state  # one atomic byte store

    @property
    def owner_pid(self) -> int:
        return _HEADER.unpack_from(self.seg.buf, 0)[5]

    @property
    def slots_used(self) -> int:
        return _HEADER.unpack_from(self.seg.buf, 0)[6]

    def _publish_slots_used(self, n: int) -> None:
        struct.pack_into("<I", self.seg.buf, _HEADER.size - 4, n)

    # -- owner: allocation / commit / reclaim --------------------------------
    def alloc(self, nbytes: int, idbytes: bytes = NO_ID) -> int | None:
        """Reserve a chunk + slot for ``nbytes``; returns the slot index or
        None when this arena cannot fit it.  The slot is WRITING (invisible
        to readers) until :meth:`commit`."""
        if not self.owner:
            raise RuntimeError("only the creating process allocates")
        klass = size_class(nbytes)
        chunk = 1 << klass
        if chunk > self.size - self.data_off:
            return None
        if self.sanitize:
            self._drain_quarantine()
        free = self._free_chunks.get(klass)
        if free:
            offset = free.pop()
        elif self._bump + chunk <= self.size:
            offset = self._bump
            self._bump += chunk
        else:
            self.reclaim()
            if self.sanitize:
                self._drain_quarantine()
            free = self._free_chunks.get(klass)
            if not free:
                return None
            offset = free.pop()
        slot = self._take_slot()
        if slot is None:
            self._free_chunks.setdefault(klass, []).append(offset)
            return None
        gen = self._entry(slot)[3]
        self._write_entry(slot, WRITING, klass, gen, nbytes, offset, idbytes)
        return slot

    def _take_slot(self) -> int | None:
        if self._free_slots:
            return self._free_slots.pop()
        if self._next_slot < self.nslots:
            slot = self._next_slot
            self._next_slot += 1
            self._publish_slots_used(self._next_slot)
            return slot
        self.reclaim()
        return self._free_slots.pop() if self._free_slots else None

    def slot_view(self, slot: int) -> memoryview:
        """Writable view of the slot's payload span (producer memcpy
        target)."""
        _st, _k, _pad, _gen, _freq, size, offset, _id = self._entry(slot)
        return self.seg.buf[offset:offset + size]

    def commit(self, slot: int) -> int:
        """Flip the slot to COMMITTED (the publication point); returns the
        slot's generation, which the key must carry."""
        gen = self._entry(slot)[3]
        self._set_state(slot, COMMITTED)
        return gen

    def free(self, slot: int, gen: int | None = None) -> bool:
        """Owner-side reclaim: generation bump kills stale keys, chunk goes
        back on its class free list (via a one-free quarantine, poisoned
        0xDE, when sanitizing)."""
        if not self.owner:
            raise RuntimeError("only the creating process frees slots")
        state, klass, _pad, cur_gen, _freq, size, offset, _id = \
            self._entry(slot)
        if state == FREE or (gen is not None and gen != cur_gen):
            return False
        if self.sanitize:
            self._check_exports(slot, cur_gen)
        next_gen = (cur_gen + 1) & 0xFFFFFFFF
        if next_gen == _NO_FREQ:          # never collide with the sentinel
            next_gen = 0
        self._write_entry(slot, FREE, 0, next_gen, 0, 0, NO_ID)
        if self.sanitize:
            if size:
                self.seg.buf[offset:offset + size] = \
                    bytes([_san.POISON_BYTE]) * size
            self._epoch += 1
            self._quarantine.append((klass, offset, self._epoch))
        else:
            self._free_chunks.setdefault(klass, []).append(offset)
        self._free_slots.append(slot)
        return True

    # -- sanitizer hooks -----------------------------------------------------
    def _drain_quarantine(self) -> None:
        """Release quarantined chunks freed strictly before the newest
        free: a use-after-free view must observe poison at least until
        another free happens, never a silently-recycled object."""
        if not self._quarantine:
            return
        keep: list[tuple[int, int, int]] = []
        for klass, offset, epoch in self._quarantine:
            if epoch < self._epoch:
                self._free_chunks.setdefault(klass, []).append(offset)
            else:
                keep.append((klass, offset, epoch))
        self._quarantine = keep

    def _check_exports(self, slot: int, gen: int) -> None:
        """Raise ``use-after-free-view`` if this process still holds a live
        zero-copy view of the slot being freed."""
        recs = self._exports.get(slot)
        if not recs:
            return
        # registry ref + getrefcount's argument = 2; anything above means
        # a caller still holds the view
        live = [r for r in recs if sys.getrefcount(r.view) > 2]
        if not live:
            self._exports.pop(slot, None)
            return
        self._exports[slot] = live
        for rec in live:
            if rec.gen == gen:
                raise _san.SanitizerError(
                    "use-after-free-view",
                    f"arena {self.name} slot {slot} gen {gen}: freeing a "
                    f"chunk while a zero-copy view of it is still live in "
                    f"this process.  View borrowed at:\n{rec.site}"
                    f"serialize.materialize the object (or drop the view) "
                    f"before the last decref/evict.")

    def reclaim(self) -> int:
        """Sweep slots with a matching free request (non-owner evictions)
        back onto the free lists.  Called lazily, under allocation
        pressure."""
        n = 0
        for slot in range(self._next_slot):
            state, _k, _pad, gen, freq = self._entry(slot)[:5]
            if state == COMMITTED and freq == gen:
                if self.free(slot):
                    n += 1
        return n

    # -- any process: read / existence / eviction ----------------------------
    def read(self, slot: int, gen: int) -> memoryview | None:
        """Zero-copy view of a committed slot's payload, or None when the
        slot was never committed, evicted, freed-on-request, or recycled
        (generation mismatch)."""
        if not 0 <= slot < self.nslots:
            return None
        state, _k, _pad, cur_gen, freq, size, offset, _id = self._entry(slot)
        if state != COMMITTED or cur_gen != gen or freq == gen:
            return None
        if offset + size > self.size:
            return None
        view = self.seg.buf[offset:offset + size]
        if self.sanitize:
            recs = self._exports.setdefault(slot, [])
            if len(recs) >= 8:  # prune dropped views before growing
                recs[:] = [r for r in recs
                           if sys.getrefcount(r.view) > 2]
            recs.append(_Export(view, gen, _san.borrow_site(skip=2)))
        return view

    def committed(self, slot: int, gen: int) -> bool:
        if not 0 <= slot < self.nslots:
            return False
        state, _k, _pad, cur_gen, freq = self._entry(slot)[:5]
        return state == COMMITTED and cur_gen == gen and freq != gen

    def request_free(self, slot: int, gen: int) -> None:
        """Non-owner eviction: publish a free request TAGGED with the
        generation being evicted (never touching the owner-only state
        byte).  If the owner recycled the slot concurrently, the stale tag
        matches nothing and the new object is untouched — the worst
        concurrent interleaving delays an eviction, never corrupts one."""
        if not 0 <= slot < self.nslots:
            return
        state, _k, _pad, cur_gen = self._entry(slot)[:4]
        if state == COMMITTED and cur_gen == gen:
            struct.pack_into("<I", self.seg.buf,
                             self._slot_off(slot) + _FREQ_OFF, gen)

    def find_id(self, idbytes: bytes) -> tuple[int, int] | None:
        """Locate a committed slot by its embedded id (the reserved-key
        redirect path); returns (slot, gen) or None.  Scans only up to the
        arena's high-water mark."""
        for slot in range(min(self.slots_used, self.nslots)):
            state, _k, _pad, gen, freq, _size, _off, sid = self._entry(slot)
            if state == COMMITTED and freq != gen and sid == idbytes:
                return slot, gen
        return None

    def live_slots(self) -> Iterator[tuple[int, int, int]]:
        """Yield (slot, gen, size) for every committed slot."""
        for slot in range(min(self.slots_used, self.nslots)):
            state, _k, _pad, gen, freq, size, _off, _id = self._entry(slot)
            if state == COMMITTED and freq != gen:
                yield slot, gen, size

    def slot_records(self) -> Iterator[tuple[int, int, int, bytes]]:
        """Yield (slot, gen, size, idbytes) for every committed slot —
        the sweep-report view of what an arena still holds."""
        for slot in range(min(self.slots_used, self.nslots)):
            state, _k, _pad, gen, freq, size, _off, sid = self._entry(slot)
            if state == COMMITTED and freq != gen:
                yield slot, gen, size, sid

    def enable_sanitizer(self) -> None:
        self.sanitize = True

    def close(self) -> None:
        close_mapping(self.seg)

    def unlink(self) -> None:
        try:
            _unlink_segment(self.seg)
        except FileNotFoundError:
            pass


class _Export:
    """One zero-copy view handed out by :meth:`Arena.read` (sanitizer).

    A ``memoryview`` is neither weakref-able nor subclassable, so liveness
    is judged by refcount: the registry holds exactly one reference, and
    ``sys.getrefcount`` adds one for its argument — above 2 means a caller
    still holds the view.  Same-process tracking only, by construction.
    """

    __slots__ = ("view", "gen", "site")

    def __init__(self, view: memoryview, gen: int, site: str) -> None:
        self.view = view
        self.gen = gen
        self.site = site


class ArenaPool:
    """The owner-side pool a producer allocates from, plus the consumer-side
    attach cache, over one *registry directory*.

    The registry dir holds one tiny marker file per arena
    (``<segment>.arena`` containing the owner pid) written once at arena
    creation — the only filesystem traffic of the data plane.  Consumers
    list it to discover arenas created by other processes.
    """

    def __init__(self, registry_dir: str,
                 arena_size: int = DEFAULT_ARENA_SIZE,
                 nslots: int = DEFAULT_NSLOTS,
                 sanitize: bool | None = None) -> None:
        self._dir = Path(registry_dir)
        self._dir.mkdir(parents=True, exist_ok=True)
        self.arena_size = int(arena_size)
        self.nslots = int(nslots)
        self.sanitize = _san.enabled() if sanitize is None else bool(sanitize)
        self._lock = threading.RLock()
        self._owned: list[Arena] = []          # allocation order
        self._attached: dict[str, Arena | None] = {}  # name -> arena/dead
        self.last_sweep_report: list[dict[str, Any]] = []

    # -- arena lifecycle -----------------------------------------------------
    def _marker(self, name: str) -> Path:
        return self._dir / f"{name}.arena"

    def _create_arena(self, size: int, nslots: int) -> Arena:
        name = f"psja_{uuid.uuid4().hex[:16]}"
        arena = Arena(name, create=True, size=size, nslots=nslots,
                      sanitize=self.sanitize)
        self._marker(name).write_text(str(os.getpid()))
        self._owned.append(arena)
        self._attached[name] = arena
        return arena

    def attach(self, name: str) -> Arena | None:
        """Consumer-side cached attach (one mmap per arena, ever)."""
        with self._lock:
            arena = self._attached.get(name, _ABSENT)
            if arena is not _ABSENT:
                return arena
            try:
                arena = Arena(name, sanitize=self.sanitize)
            except (FileNotFoundError, ValueError):
                arena = None
            self._attached[name] = arena
            return arena

    def enable_sanitizer(self) -> None:
        """Turn sanitizing on for this pool and every mapped arena."""
        with self._lock:
            self.sanitize = True
            for arena in self._owned:
                arena.enable_sanitizer()
            for arena in self._attached.values():
                if arena is not None:
                    arena.enable_sanitizer()

    def discover(self) -> list[str]:
        """Arena names published in the registry dir (any process)."""
        return [p.name[:-len(".arena")] for p in self._dir.glob("*.arena")]

    # -- the data-plane hot path ---------------------------------------------
    def put(self, segments, nbytes: int,
            idbytes: bytes = NO_ID) -> tuple[str, int, int]:
        """Allocate a slot, scatter ``segments`` into it, commit.  Returns
        ``(arena_name, slot, gen)``.  One memcpy per segment + one atomic
        state store — no syscalls once the arena exists."""
        # only the allocator bookkeeping needs the pool lock; the memcpy
        # + commit run outside it (a WRITING slot has exactly one writer),
        # so concurrent threads' payload copies overlap
        with self._lock:
            arena, slot = self._alloc(nbytes, idbytes)
        copy_segments_into(segments, arena.slot_view(slot))
        gen = arena.commit(slot)
        return arena.name, slot, gen

    def _alloc(self, nbytes: int, idbytes: bytes) -> tuple[Arena, int]:
        for arena in self._owned:
            slot = arena.alloc(nbytes, idbytes)
            if slot is not None:
                return arena, slot
        # second pass: reclaim consumer-side frees, then retry
        for arena in self._owned:
            if arena.reclaim():
                slot = arena.alloc(nbytes, idbytes)
                if slot is not None:
                    return arena, slot
        # grow: oversized objects get a dedicated overflow arena; everything
        # else gets a fresh standard arena
        chunk = 1 << size_class(nbytes)
        if chunk > self.arena_size // 2:
            arena = self._create_arena(chunk, nslots=8)
        else:
            arena = self._create_arena(self.arena_size, self.nslots)
        slot = arena.alloc(nbytes, idbytes)
        if slot is None:  # pragma: no cover - fresh arena always fits
            raise MemoryError(f"cannot place {nbytes} byte object")
        return arena, slot

    # -- block-granular reservation (the KV-paging producer path) ------------
    def reserve_direct(self, nbytes: int, idbytes: bytes = NO_ID,
                       ) -> tuple[tuple[str, int, int], memoryview]:
        """Allocate a WRITING slot and hand back its writable payload view.

        Producers whose payload is computed straight into channel memory
        (KV-cache blocks, pre-sized tensors) fill the view in place —
        zero staging copies — then publish with :meth:`commit_direct`.
        Returns ``((arena_name, slot, gen), view)``; the generation is
        already final (``commit`` only flips the state byte), so the
        caller may mint the object's key before committing.
        """
        with self._lock:
            arena, slot = self._alloc(nbytes, idbytes)
        gen = arena._entry(slot)[3]
        return (arena.name, slot, gen), arena.slot_view(slot)

    def commit_direct(self, name: str, slot: int) -> int:
        """Publish a slot reserved via :meth:`reserve_direct` (the atomic
        state-byte store); returns the slot's generation."""
        with self._lock:
            arena = self._attached.get(name)
        if arena is None or not arena.owner:
            raise ValueError(f"cannot commit into non-owned arena {name!r}")
        return arena.commit(slot)

    def free(self, name: str, slot: int, gen: int) -> None:
        """Evict: owner frees in place, non-owner requests the free."""
        with self._lock:
            arena = self.attach(name)
            if arena is None:
                return
            if arena.owner:
                arena.free(slot, gen)
            else:
                arena.request_free(slot, gen)

    def find_id(self, idbytes: bytes) -> tuple[str, int, int] | None:
        """Reserved-key redirect: locate ``idbytes`` across every
        discoverable arena; returns (arena_name, slot, gen) or None."""
        with self._lock:
            names = set(self._attached) | set(self.discover())
            for name in names:
                arena = self.attach(name)
                if arena is None:
                    continue
                hit = arena.find_id(idbytes)
                if hit is not None:
                    return name, hit[0], hit[1]
        return None

    # -- registry hygiene ----------------------------------------------------
    def sweep(self, *, clear: bool = False) -> int:
        """Registry-dir startup scan.

        Always: drop ``.{id}.tmp`` sidecar orphans (a pre-arena producer
        that crashed between write and rename) and markers whose segment no
        longer exists.  With ``clear=True`` additionally unlink arenas whose
        owner process is dead (nothing will reclaim them) — and with it,
        legacy ``*.json`` sidecars + their segments from the pre-arena
        layout, so a restarted registry dir cannot leak segments.

        Every dead-owner arena's surviving objects are itemized (arena,
        slot, gen, size, owner pid, embedded id) in ``last_sweep_report``
        — whether or not they are reclaimed — so CI output shows *what*
        leaked, not just a count.  Sanitizing pools also print the report
        to stderr.
        """
        n = 0
        report: list[dict[str, Any]] = []
        for tmp in self._dir.glob(".*.tmp"):
            tmp.unlink(missing_ok=True)
            n += 1
        for marker in self._dir.glob("*.arena"):
            name = marker.name[:-len(".arena")]
            try:
                arena = Arena(name)
            except (FileNotFoundError, ValueError):
                marker.unlink(missing_ok=True)
                n += 1
                continue
            try:
                pid = arena.owner_pid
                alive = _pid_alive(pid)
                if not alive:
                    for slot, gen, size, sid in arena.slot_records():
                        report.append({
                            "arena": name, "slot": slot, "gen": gen,
                            "size": size, "owner_pid": pid,
                            "reclaimed": bool(clear),
                            "id": sid.hex() if sid != NO_ID else None,
                        })
                if clear and not alive:
                    arena.unlink()
                    marker.unlink(missing_ok=True)
                    n += 1
            finally:
                if self._attached.get(name) is not arena:
                    arena.close()
        self.last_sweep_report = report
        if self.sanitize and report:
            for rec in report:
                print(f"[arena-sweep] orphaned slot "
                      f"{rec['arena']}:{rec['slot']}@{rec['gen']} "
                      f"size={rec['size']} owner_pid={rec['owner_pid']} "
                      f"(dead) id={rec['id']} "
                      f"reclaimed={rec['reclaimed']}", file=sys.stderr)
        if clear:
            for sidecar in self._dir.glob("*.json"):
                try:
                    import json

                    seg_name = json.loads(sidecar.read_text()).get("segment")
                    if seg_name:
                        seg = _open_segment(seg_name)
                        close_mapping(seg)
                        _unlink_segment(seg)
                except (FileNotFoundError, ValueError, KeyError):
                    pass
                sidecar.unlink(missing_ok=True)
                n += 1
        return n

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {
                "n_owned_arenas": len(self._owned),
                "n_attached_arenas": sum(
                    1 for a in self._attached.values() if a is not None),
                "owned_bytes": sum(a.size for a in self._owned),
            }

    def close(self) -> None:
        """Unlink owned arenas (+ markers), detach consumer mappings."""
        with self._lock:
            owned, self._owned = self._owned, []
            attached, self._attached = self._attached, {}
        for arena in owned:
            self._marker(arena.name).unlink(missing_ok=True)
            arena.close()
            arena.unlink()
        for arena in attached.values():
            if arena is not None and not arena.owner:
                arena.close()


_ABSENT = object()
