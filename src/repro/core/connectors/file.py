"""FileConnector — mediated communication via a shared file system (§4.1.1).

Writes are atomic (tmp + rename) so concurrent readers never observe partial
objects; this is what makes the connector safe as a checkpoint target.
"""
from __future__ import annotations

import os
import uuid
from pathlib import Path
from typing import Any

from repro.core.connector import BaseConnector, Key
from repro.core.serialize import as_segments


class FileConnector(BaseConnector):
    def __init__(self, store_dir: str, clear: bool = False) -> None:
        self.store_dir = str(store_dir)
        self._dir = Path(store_dir)
        self._dir.mkdir(parents=True, exist_ok=True)
        if clear:
            for f in self._dir.glob("*.obj"):
                f.unlink(missing_ok=True)

    def _path(self, object_id: str) -> Path:
        return self._dir / f"{object_id}.obj"

    def _write(self, object_id: str, blob) -> None:
        tmp = self._dir / f".{object_id}.tmp"
        with open(tmp, "wb") as f:
            for seg in as_segments(blob):  # writev-style, no join copy
                f.write(seg)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._path(object_id))

    def put(self, blob) -> Key:
        object_id = uuid.uuid4().hex
        self._write(object_id, blob)
        return ("file", self.store_dir, object_id)

    # -- futures: pre-data keys (the atomic rename means a cross-process
    # waiter polling exists() never observes a partial object) -------------
    def reserve(self) -> Key:
        return ("file", self.store_dir, uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._write(key[2], blob)
        self.announce(key)

    def get(self, key: Key) -> bytes | None:
        path = self._path(key[2])
        try:
            return path.read_bytes()
        except FileNotFoundError:
            return None

    def exists(self, key: Key) -> bool:
        return self._path(key[2]).exists()

    def evict(self, key: Key) -> None:
        self._path(key[2]).unlink(missing_ok=True)

    def _lifetime_scope(self):
        return self.store_dir      # reconnections share the count table

    def config(self) -> dict[str, Any]:
        return {"store_dir": self.store_dir}
