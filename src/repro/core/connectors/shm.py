"""SharedMemoryConnector — zero-copy intra-node channel (§4.1.3 role).

Plays the role of the paper's Margo/UCX RDMA-backed distributed memory for
node-local producers/consumers: objects live in named POSIX shared-memory
segments.  ``put`` writes frame segments straight into the mapping (no join
copy) and ``get`` returns a *mapped memoryview* of the segment — the consumer
deserializes zero-copy out of shared memory; no socket, no ``bytes()`` copy.

Hardware adaptation note (DESIGN.md §2): no RDMA NIC exists in this container;
POSIX shm is the intra-node analog of memory-to-memory transfer.  Cross-node
traffic falls to SocketConnector/KVServerConnector, as the paper's ZMQ
fallback does.
"""
from __future__ import annotations

import atexit
import inspect
import json
import threading
import uuid
from collections import OrderedDict
from multiprocessing import shared_memory
from pathlib import Path
from typing import Any

from repro.core.connector import BaseConnector, Key
from repro.core.serialize import as_segments, frame_nbytes

# Ownership is explicit (the on-disk index + close()), so segments should
# NEVER be handed to multiprocessing's resource tracker.  Python >= 3.13 has
# track=False; earlier versions get an explicit unregister after attach.
_HAS_TRACK = "track" in inspect.signature(
    shared_memory.SharedMemory.__init__).parameters


def _open_segment(name: str, *, create: bool = False,
                  size: int = 0) -> shared_memory.SharedMemory:
    kwargs: dict[str, Any] = {"track": False} if _HAS_TRACK else {}
    if create:
        seg = shared_memory.SharedMemory(name=name, create=True,
                                         size=max(1, size), **kwargs)
    else:
        seg = shared_memory.SharedMemory(name=name, **kwargs)
    if not _HAS_TRACK:
        try:
            from multiprocessing import resource_tracker

            resource_tracker.unregister(seg._name, "shared_memory")
        except Exception:  # pragma: no cover - tracker internals vary
            pass
    return seg


def _unlink_segment(seg: shared_memory.SharedMemory) -> None:
    """Unlink, balancing the tracker bookkeeping on Python < 3.13 (unlink
    sends an unregister; we already unregistered at open)."""
    if not _HAS_TRACK:
        try:
            from multiprocessing import resource_tracker

            resource_tracker.register(seg._name, "shared_memory")
        except Exception:  # pragma: no cover
            pass
    seg.unlink()


class SharedMemoryConnector(BaseConnector):
    """Named-segment shm store with an on-disk index for discovery.

    ``registry_dir`` is a small shared directory (tmpfs is fine) holding one
    JSON sidecar per object: {"segment": name, "size": n}.  Data never touches
    the file system — only 60-byte index entries do.

    ``get`` keeps the attached segment mapped (so the returned view stays
    valid) until ``evict``/``close``; a mapping whose views are still exported
    at close time is left for the GC rather than invalidated underfoot.
    """

    # mapped-reader cache bound: each entry holds 2 fds + one mapping, so
    # cap it and LRU-close (views still exported survive via _close_segment)
    MAX_OPEN_SEGMENTS = 64

    def __init__(self, registry_dir: str, clear: bool = False) -> None:
        self.registry_dir = str(registry_dir)
        self._dir = Path(registry_dir)
        self._dir.mkdir(parents=True, exist_ok=True)
        self._owned: set[str] = set()
        self._open: OrderedDict[
            str, tuple[shared_memory.SharedMemory, int]] = OrderedDict()
        self._lock = threading.Lock()
        if clear:
            for f in self._dir.glob("*.json"):
                self._evict_entry(f)
        atexit.register(self.close)

    # -- helpers ------------------------------------------------------------
    def _idx(self, object_id: str) -> Path:
        return self._dir / f"{object_id}.json"

    def _close_segment(self, seg: shared_memory.SharedMemory) -> None:
        try:
            seg.close()
        except BufferError:
            # A consumer still holds a zero-copy view: the mapping must stay
            # alive until that view dies.  Drop the fd now and detach the
            # wrapper from the mmap (the exported views keep it referenced;
            # GC unmaps with the last view) so __del__ doesn't re-raise.
            try:
                import os

                if seg._fd >= 0:
                    os.close(seg._fd)
                    seg._fd = -1
                seg._mmap = None
                seg._buf = None
            except Exception:  # pragma: no cover - stdlib internals shift
                pass

    def _evict_entry(self, idx_path: Path) -> None:
        try:
            meta = json.loads(idx_path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return
        idx_path.unlink(missing_ok=True)
        try:
            seg = _open_segment(meta["segment"])
            self._close_segment(seg)
            _unlink_segment(seg)
        except FileNotFoundError:
            pass

    # -- Connector ops -------------------------------------------------------
    def _put_object(self, object_id: str, blob) -> None:
        seg_name = f"psj_{object_id[:24]}"
        nbytes = frame_nbytes(blob)
        seg = _open_segment(seg_name, create=True, size=nbytes)
        pos = 0
        for s in as_segments(blob):  # scatter directly into the mapping
            mv = memoryview(s).cast("B")
            seg.buf[pos:pos + mv.nbytes] = mv
            pos += mv.nbytes
        seg.close()
        tmp = self._dir / f".{object_id}.tmp"
        tmp.write_text(json.dumps({"segment": seg_name, "size": nbytes}))
        tmp.replace(self._idx(object_id))
        with self._lock:
            self._owned.add(object_id)

    def put(self, blob) -> Key:
        object_id = uuid.uuid4().hex
        self._put_object(object_id, blob)
        return ("shm", self.registry_dir, object_id)

    # -- futures: pre-data keys (the index-sidecar rename is the commit
    # point, so waiters never observe a half-written segment) --------------
    def reserve(self) -> Key:
        return ("shm", self.registry_dir, uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._put_object(key[2], blob)
        self.announce(key)

    def get(self, key: Key):
        object_id = key[2]
        with self._lock:
            cached = self._open.get(object_id)
            if cached is not None:
                self._open.move_to_end(object_id)
                seg, size = cached
                return seg.buf[:size]
        try:
            meta = json.loads(self._idx(object_id).read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None
        try:
            seg = _open_segment(meta["segment"])
        except FileNotFoundError:
            return None
        stale = []
        with self._lock:
            raced = self._open.get(object_id)
            if raced is not None:            # lost a concurrent first-get
                stale.append(seg)
                seg = raced[0]
            else:
                self._open[object_id] = (seg, meta["size"])
                self._open.move_to_end(object_id)
                while len(self._open) > self.MAX_OPEN_SEGMENTS:
                    _, (old, _sz) = self._open.popitem(last=False)
                    stale.append(old)
        for s in stale:
            self._close_segment(s)
        return seg.buf[:meta["size"]]

    def exists(self, key: Key) -> bool:
        return self._idx(key[2]).exists()

    def evict(self, key: Key) -> None:
        object_id = key[2]
        with self._lock:
            cached = self._open.pop(object_id, None)
        if cached is not None:
            self._close_segment(cached[0])
        self._evict_entry(self._idx(object_id))
        with self._lock:
            self._owned.discard(object_id)

    def _lifetime_scope(self):
        return self.registry_dir   # reconnections share the count table

    def config(self) -> dict[str, Any]:
        return {"registry_dir": self.registry_dir}

    def close(self) -> None:
        """Unmap reader segments and unlink segments created by this process."""
        with self._lock:
            open_segs, self._open = self._open, {}
            owned, self._owned = self._owned, set()
        for seg, _ in open_segs.values():
            self._close_segment(seg)
        for object_id in owned:
            self._evict_entry(self._idx(object_id))
        self._drop_lifetime_state()
