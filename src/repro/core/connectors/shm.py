"""SharedMemoryConnector — zero-copy intra-node channel (§4.1.3 role).

Plays the role of the paper's Margo/UCX RDMA-backed distributed memory for
node-local producers/consumers: objects live in named POSIX shared-memory
segments, so ``get`` is a page-mapped read, not a socket copy.

Hardware adaptation note (DESIGN.md §2): no RDMA NIC exists in this container;
POSIX shm is the intra-node analog of memory-to-memory transfer.  Cross-node
traffic falls to SocketConnector/KVServerConnector, as the paper's ZMQ
fallback does.
"""
from __future__ import annotations

import atexit
import json
import threading
import uuid
from multiprocessing import shared_memory
from pathlib import Path
from typing import Any

from repro.core.connector import BaseConnector, Key

# Ownership is explicit (the on-disk index + close()), so segments are NEVER
# handed to multiprocessing's resource tracker: track=False (Python >= 3.13).


class SharedMemoryConnector(BaseConnector):
    """Named-segment shm store with an on-disk index for discovery.

    ``registry_dir`` is a small shared directory (tmpfs is fine) holding one
    JSON sidecar per object: {"segment": name, "size": n}.  Data never touches
    the file system — only 60-byte index entries do.
    """

    def __init__(self, registry_dir: str, clear: bool = False) -> None:
        self.registry_dir = str(registry_dir)
        self._dir = Path(registry_dir)
        self._dir.mkdir(parents=True, exist_ok=True)
        self._owned: set[str] = set()
        self._lock = threading.Lock()
        if clear:
            for f in self._dir.glob("*.json"):
                self._evict_entry(f)
        atexit.register(self.close)

    # -- helpers ------------------------------------------------------------
    def _idx(self, object_id: str) -> Path:
        return self._dir / f"{object_id}.json"

    def _evict_entry(self, idx_path: Path) -> None:
        try:
            meta = json.loads(idx_path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return
        idx_path.unlink(missing_ok=True)
        try:
            seg = shared_memory.SharedMemory(name=meta["segment"], track=False)
            seg.close()
            seg.unlink()
        except FileNotFoundError:
            pass

    # -- Connector ops -------------------------------------------------------
    def put(self, blob: bytes) -> Key:
        object_id = uuid.uuid4().hex
        seg_name = f"psj_{object_id[:24]}"
        seg = shared_memory.SharedMemory(name=seg_name, create=True,
                                         size=max(1, len(blob)), track=False)
        seg.buf[: len(blob)] = blob
        seg.close()
        tmp = self._dir / f".{object_id}.tmp"
        tmp.write_text(json.dumps({"segment": seg_name, "size": len(blob)}))
        tmp.replace(self._idx(object_id))
        with self._lock:
            self._owned.add(object_id)
        return ("shm", self.registry_dir, object_id)

    def get(self, key: Key) -> bytes | None:
        try:
            meta = json.loads(self._idx(key[2]).read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None
        try:
            seg = shared_memory.SharedMemory(name=meta["segment"], track=False)
        except FileNotFoundError:
            return None
        try:
            return bytes(seg.buf[: meta["size"]])
        finally:
            seg.close()

    def exists(self, key: Key) -> bool:
        return self._idx(key[2]).exists()

    def evict(self, key: Key) -> None:
        self._evict_entry(self._idx(key[2]))
        with self._lock:
            self._owned.discard(key[2])

    def config(self) -> dict[str, Any]:
        return {"registry_dir": self.registry_dir}

    def close(self) -> None:
        """Unlink segments created by this process (producer-side cleanup)."""
        with self._lock:
            owned, self._owned = self._owned, set()
        for object_id in owned:
            self._evict_entry(self._idx(object_id))
