"""SharedMemoryConnector — slab-arena zero-copy intra-node channel (§4.1.3).

Plays the role of the paper's Margo/UCX RDMA-backed distributed memory for
node-local producers/consumers.  Objects live in a small number of large
pre-created shared-memory **arenas** (see :mod:`repro.core.arena`): ``put``
is one slab allocation + one memcpy per frame segment + one atomic
commit-byte store; ``get`` is a cached arena attach + a slot-entry read +
a zero-copy ``memoryview`` slice the consumer deserializes straight out of
shared memory.  No per-object segments, no filesystem sidecars, no
syscalls on the steady-state hot path.

Key layout: ``("shm", registry_dir, object_id)`` where ``object_id`` is
``"{arena}.{slot}.{gen}"`` — the slot header in the arena IS the object
directory, and the generation makes keys of recycled slots read as
missing instead of aliasing new data.  Reserved keys (futures) are
``"r{uuid}"``: ``put_to`` embeds the uuid in the slot entry and consumers
resolve it by scanning the arenas' slot tables (the rare pre-data path;
the hot path never scans).

Mapped-view lifetime: views returned by ``get`` stay *valid* until the
consumer's connector closes (and survive even that while exported), but
their *contents* are only stable until the object is evicted — after
which the owner may recycle the chunk.  Use refcounts/leases to pin
objects consumers are still reading.

Hardware adaptation note (DESIGN.md §2): no RDMA NIC exists in this
container; POSIX shm is the intra-node analog of memory-to-memory
transfer.  Cross-node traffic falls to SocketConnector/KVServerConnector,
as the paper's ZMQ fallback does.
"""
from __future__ import annotations

import atexit
import uuid
from typing import Any

from repro.core.arena import (DEFAULT_ARENA_SIZE, DEFAULT_NSLOTS, ArenaPool,
                              NO_ID)
from repro.core.connector import BaseConnector, Key
from repro.core.serialize import as_segments, frame_nbytes

_RESERVED = "r"     # reserved-key object_id prefix (no "." — arena ids have 3)


class SharedMemoryConnector(BaseConnector):
    """Arena-backed shm store.

    ``registry_dir`` is a small shared directory (tmpfs is fine) holding one
    marker file per *arena* (written once at arena creation) — per-object
    traffic never touches the filesystem.  ``arena_size``/``nslots`` size
    the slabs this process creates as a producer; consumers attach whatever
    the registry advertises regardless of their own settings.
    """

    # gets return views of arena memory the owner may recycle post-evict:
    # lifecycle-bound Store resolves materialize before dropping their ref
    borrows_get = True

    def __init__(self, registry_dir: str, clear: bool = False,
                 arena_size: int = DEFAULT_ARENA_SIZE,
                 nslots: int = DEFAULT_NSLOTS) -> None:
        self.registry_dir = str(registry_dir)
        self.arena_size = int(arena_size)
        self.nslots = int(nslots)
        self._pool = ArenaPool(self.registry_dir, self.arena_size,
                               self.nslots)
        # orphan sweep: tmp sidecars + dead markers always; with clear=True
        # also dead-owner arenas and legacy per-object segments
        self._pool.sweep(clear=clear)
        # reserved-id -> located object_id (the scan runs once per id)
        self._resolved: dict[str, str] = {}
        atexit.register(self.close)

    # -- id plumbing ---------------------------------------------------------
    @staticmethod
    def _encode(arena: str, slot: int, gen: int) -> str:
        return f"{arena}.{slot}.{gen}"

    def _locate(self, object_id: str) -> tuple[str, int, int] | None:
        """Resolve an object_id to (arena, slot, gen); reserved ids go
        through the slot-table scan (cached after the first hit)."""
        if object_id.startswith(_RESERVED):
            hit = self._resolved.get(object_id)
            if hit is None:
                found = self._pool.find_id(
                    bytes.fromhex(object_id[len(_RESERVED):]))
                if found is None:
                    return None
                hit = self._encode(*found)
                self._resolved[object_id] = hit
            object_id = hit
        try:
            arena, slot, gen = object_id.rsplit(".", 2)
            return arena, int(slot), int(gen)
        except ValueError:
            return None

    # -- Connector ops -------------------------------------------------------
    def put(self, blob) -> Key:
        loc = self._pool.put(as_segments(blob), frame_nbytes(blob))
        return ("shm", self.registry_dir, self._encode(*loc))

    # -- futures: pre-data keys (the slot's commit byte is the publication
    # point, so waiters never observe a half-written payload) ---------------
    def reserve(self) -> Key:
        return ("shm", self.registry_dir, _RESERVED + uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        object_id = key[2]
        idbytes = (bytes.fromhex(object_id[len(_RESERVED):])
                   if object_id.startswith(_RESERVED) else NO_ID)
        loc = self._pool.put(as_segments(blob), frame_nbytes(blob), idbytes)
        if idbytes != NO_ID:
            self._resolved[object_id] = self._encode(*loc)
        self.announce(key)

    def get(self, key: Key):
        loc = self._locate(key[2])
        if loc is None:
            return None
        arena = self._pool.attach(loc[0])
        if arena is None:
            return None
        return arena.read(loc[1], loc[2])

    # -- block-granular reservation (KV-cache paging) ------------------------
    # A ``put`` whose payload the caller writes in place: reserve hands out
    # the slot's writable view, the producer fills it (e.g. via
    # ``np.frombuffer``), commit_block flips the publication byte.  Zero
    # staging copies between the compute and the shared mapping.
    supports_blocks = True

    def reserve_block(self, nbytes: int) -> tuple[Key, memoryview]:
        loc, view = self._pool.reserve_direct(nbytes)
        return ("shm", self.registry_dir, self._encode(*loc)), view

    def commit_block(self, key: Key) -> None:
        loc = self._locate(key[2])
        if loc is None:
            raise KeyError(f"not an arena key: {key}")
        self._pool.commit_direct(loc[0], loc[1])

    def exists(self, key: Key) -> bool:
        loc = self._locate(key[2])
        if loc is None:
            return False
        arena = self._pool.attach(loc[0])
        return arena is not None and arena.committed(loc[1], loc[2])

    def evict(self, key: Key) -> None:
        loc = self._locate(key[2])
        if loc is None:
            return
        self._pool.free(*loc)
        if key[2].startswith(_RESERVED):
            self._resolved.pop(key[2], None)

    def _lifetime_scope(self):
        return self.registry_dir   # reconnections share the count table

    def config(self) -> dict[str, Any]:
        return {"registry_dir": self.registry_dir,
                "arena_size": self.arena_size, "nslots": self.nslots}

    def stats(self) -> dict[str, Any]:
        return self._pool.stats()

    def enable_sanitizer(self) -> None:
        """Poison-on-free + quarantine + exported-view tracking for every
        arena this connector maps (``Store(..., sanitize=True)`` calls
        this; ``REPRO_SANITIZE=1`` enables it at pool construction)."""
        self._pool.enable_sanitizer()

    def close(self) -> None:
        """Unlink arenas created by this process, detach attached ones.
        Mappings with exported zero-copy views stay alive for the GC."""
        self._pool.close()
        self._resolved.clear()
        self._drop_lifetime_state()
