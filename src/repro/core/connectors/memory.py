"""In-process memory connector.

Not a distributed channel — it backs unit tests, the Store cache layer, and
single-process workflows.  ``config()`` round-trips to an *empty* store in a
new process by design (documented paper-divergence: the real analog is the
process-local portion of Margo/UCX stores).
"""
from __future__ import annotations

import itertools
import threading
import uuid
from typing import Any

from repro.core.connector import BaseConnector, Key
from repro.core.serialize import join_frame

# Keyed globally so that config() reconnection within the same process sees
# the same data (mirrors how a respawned RedisConnector sees the same server).
_STORES: dict[str, dict[Key, bytes]] = {}
_LOCK = threading.Lock()


class LocalMemoryConnector(BaseConnector):
    def __init__(self, store_id: str | None = None) -> None:
        self.store_id = store_id or uuid.uuid4().hex
        with _LOCK:
            self._data = _STORES.setdefault(self.store_id, {})
        self._counter = itertools.count()

    def put(self, blob) -> Key:
        key = ("mem", self.store_id, uuid.uuid4().hex)
        self._data[key] = join_frame(blob)
        return key

    # -- futures: pre-data keys ---------------------------------------------
    def reserve(self) -> Key:
        return ("mem", self.store_id, uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._data[tuple(key)] = join_frame(blob)
        self.announce(key)

    def get(self, key: Key) -> bytes | None:
        return self._data.get(tuple(key))

    def exists(self, key: Key) -> bool:
        return tuple(key) in self._data

    def evict(self, key: Key) -> None:
        self._data.pop(tuple(key), None)

    def _lifetime_scope(self):
        return self.store_id       # reconnections share the count table

    def config(self) -> dict[str, Any]:
        return {"store_id": self.store_id}

    def close(self) -> None:
        with _LOCK:
            _STORES.pop(self.store_id, None)
        self._drop_lifetime_state()
