"""GlobusConnector — simulated inter-site bulk file transfer (§4.2.1).

The real connector hands files to the Globus transfer service and keys carry
``(object_id, task_id)``; a resolving proxy *waits for the transfer task* to
succeed.  Offline, we reproduce exactly that control flow against a calibrated
performance model instead of a WAN:

* each *site* has a staging directory (the paper's endpoint-path mapping,
  keyed by hostname regex; here by site name / ``PSJ_SITE``),
* ``put`` stages the file at every destination site immediately but gates
  availability behind a transfer-task record whose completion time is
  ``latency + total_bytes / bandwidth`` — the paper's observed regime of
  "high bandwidth for larger transfers but not low latency for small
  transfers" (defaults: 2 s task latency, 400 MB/s),
* ``get`` polls the task and sleeps until completion, raising on a
  (simulated) failed task,
* ``put_batch`` files ONE task for many objects — the Store's
  ``proxy_batch`` then amortizes task latency, as in the paper.
"""
from __future__ import annotations

import json
import os
import time
import uuid as uuid_mod
from pathlib import Path
from typing import Any

from repro.core.connector import BaseConnector, Key
from repro.core.serialize import as_segments, frame_nbytes


class TransferError(RuntimeError):
    pass


class GlobusConnector(BaseConnector):
    def __init__(self, endpoint_map: dict[str, str], site: str | None = None,
                 bandwidth_mbps: float = 3200.0, latency_s: float = 2.0,
                 fail_rate: float = 0.0) -> None:
        self.endpoint_map = dict(endpoint_map)
        self.site = site or os.environ.get("PSJ_SITE") or next(iter(endpoint_map))
        if self.site not in self.endpoint_map:
            raise ValueError(f"site {self.site!r} not in endpoint_map")
        self.bandwidth_mbps = bandwidth_mbps
        self.latency_s = latency_s
        self.fail_rate = fail_rate
        for d in self.endpoint_map.values():
            Path(d).mkdir(parents=True, exist_ok=True)
        self._tasks_dir = Path(next(iter(self.endpoint_map.values()))) / ".tasks"
        self._tasks_dir.mkdir(exist_ok=True)

    def _lifetime_scope(self):
        return tuple(sorted(self.endpoint_map.items()))

    # -- transfer-task bookkeeping -------------------------------------------
    def _submit_task(self, total_bytes: int,
                     task_id: str | None = None) -> str:
        task_id = task_id or uuid_mod.uuid4().hex
        duration = self.latency_s + total_bytes / (self.bandwidth_mbps * 1e6 / 8)
        failed = False
        if self.fail_rate > 0.0:
            import random

            failed = random.random() < self.fail_rate
        # wall-clock on purpose: the record crosses processes via a JSON
        # file, so the deadline must be meaningful to any reader
        record = {"submitted": time.time(),  # lint: wallclock-ok
                  "ready": time.time() + duration,  # lint: wallclock-ok
                  "failed": failed}
        tmp = self._tasks_dir / f".{task_id}.tmp"
        tmp.write_text(json.dumps(record))
        tmp.replace(self._tasks_dir / f"{task_id}.json")
        return task_id

    def wait_task(self, task_id: str, poll: float = 0.05) -> None:
        path = self._tasks_dir / f"{task_id}.json"
        while True:
            try:
                rec = json.loads(path.read_text())
            except FileNotFoundError:
                raise TransferError(f"unknown transfer task {task_id}")
            if rec["failed"]:
                raise TransferError(f"transfer task {task_id} failed")
            remaining = rec["ready"] - time.time()  # lint: wallclock-ok
            if remaining <= 0:
                return
            time.sleep(min(remaining, poll) if remaining > 0 else poll)

    # -- Connector ops ---------------------------------------------------------
    def _stage(self, object_id: str, blob) -> None:
        segments = as_segments(blob)
        for d in self.endpoint_map.values():
            tmp = Path(d) / f".{object_id}.tmp"
            with open(tmp, "wb") as f:
                for seg in segments:
                    f.write(seg)
            tmp.replace(Path(d) / f"{object_id}.obj")

    def put(self, blob) -> Key:
        object_id = uuid_mod.uuid4().hex
        self._stage(object_id, blob)
        task_id = self._submit_task(frame_nbytes(blob))
        return ("globus", object_id, task_id)

    def put_batch(self, blobs) -> list[Key]:
        ids = [uuid_mod.uuid4().hex for _ in blobs]
        for oid, blob in zip(ids, blobs):
            self._stage(oid, blob)
        task_id = self._submit_task(sum(frame_nbytes(b) for b in blobs))  # ONE task
        return [("globus", oid, task_id) for oid in ids]

    # -- futures: pre-data keys.  The key pins a task id whose record does
    # not exist until ``put_to`` files the transfer; ``exists`` (and so the
    # fallback ``wait``) reports False until then, and afterwards waits out
    # the simulated transfer like any proxy resolve.
    def reserve(self) -> Key:
        return ("globus", uuid_mod.uuid4().hex, uuid_mod.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._stage(key[1], blob)
        self._submit_task(frame_nbytes(blob), task_id=key[2])
        self.announce(key)

    def get(self, key: Key) -> bytes | None:
        self.wait_task(key[2])
        path = Path(self.endpoint_map[self.site]) / f"{key[1]}.obj"
        try:
            return path.read_bytes()
        except FileNotFoundError:
            return None

    def exists(self, key: Key) -> bool:
        try:
            self.wait_task(key[2])
        except TransferError:
            return False
        return (Path(self.endpoint_map[self.site]) / f"{key[1]}.obj").exists()

    def evict(self, key: Key) -> None:
        for d in self.endpoint_map.values():
            (Path(d) / f"{key[1]}.obj").unlink(missing_ok=True)

    def config(self) -> dict[str, Any]:
        # site=None -> consumer-side PSJ_SITE decides (hostname-regex analog)
        return {"endpoint_map": self.endpoint_map, "site": None,
                "bandwidth_mbps": self.bandwidth_mbps,
                "latency_s": self.latency_s}
