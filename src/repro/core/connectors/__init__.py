"""Connector implementations (paper §4, Table 1).

=============  ============================  ==========  ==========  ===========
Connector      Storage                       Intra-site  Inter-site  Persistence
=============  ============================  ==========  ==========  ===========
LocalMemory    in-process dict               same proc   —           —
File           shared file system            ✓           —           ✓
SharedMemory   POSIX shm (Margo/UCX role)    ✓ (node)    —           —
Socket         spawned TCP store (ZMQ role)  ✓           —           —
KVServer       standalone TCP KV (Redis)     ✓           —           ✓ (opt)
Globus         simulated inter-site staging  —           ✓           ✓
Endpoint       PS-endpoint peering           ✓           ✓           ✓ (opt)
=============  ============================  ==========  ==========  ===========
"""
from repro.core.connectors.memory import LocalMemoryConnector
from repro.core.connectors.file import FileConnector
from repro.core.connectors.shm import SharedMemoryConnector
from repro.core.connectors.socket import SocketConnector
from repro.core.connectors.kvserver import KVServerConnector
from repro.core.connectors.globus import GlobusConnector
from repro.core.connectors.endpoint import EndpointConnector
from repro.core.fabric import ShardedConnector

__all__ = [
    "LocalMemoryConnector",
    "FileConnector",
    "SharedMemoryConnector",
    "SocketConnector",
    "KVServerConnector",
    "GlobusConnector",
    "EndpointConnector",
    "ShardedConnector",
]
