"""KVServerConnector — the Redis role (§4.1.2).

Connects to a standalone :mod:`repro.core.kv_tcp` server, which provides the
hybrid memory/disk semantics the paper gets from Redis: in-memory serving with
optional write-through persistence (``persist_dir``) surviving restarts.

The paper highlights that its RedisConnector is 31 lines on top of the
Connector protocol; this file is in the same spirit — the server itself lives
in ``kv_tcp.py``.
"""
from __future__ import annotations

import uuid
from typing import Any

from repro.core.connector import BaseConnector, Key
from repro.core.kv_tcp import KVClient


class KVServerConnector(BaseConnector):
    def __init__(self, host: str, port: int) -> None:
        self.host, self.port = host, int(port)
        self._client = KVClient(self.host, self.port)

    def put(self, blob) -> Key:
        object_id = uuid.uuid4().hex
        self._client.put(object_id, blob)  # gather-write, no join copy
        return ("kv", self.host, self.port, object_id)

    def put_batch(self, blobs) -> list[Key]:
        # ONE mput2 exchange: every frame's segments stream raw after the
        # header — Frames never touch msgpack, nothing is joined
        ids = [uuid.uuid4().hex for _ in blobs]
        self._client.mput(ids, blobs)
        return [("kv", self.host, self.port, i) for i in ids]

    def get(self, key: Key):
        return self._client.get(key[3])

    def get_batch(self, keys) -> list[bytes | None]:
        if not keys:
            return []
        # ONE mget2 exchange, received into one preallocated buffer
        return self._client.mget([k[3] for k in keys])

    def exists(self, key: Key) -> bool:
        return self._client.exists(key[3])

    def exists_batch(self, keys) -> list[bool]:
        return self._client.mexists([k[3] for k in keys])  # one exchange

    def evict(self, key: Key) -> None:
        self._client.evict(key[3])

    def evict_batch(self, keys) -> None:
        self._client.mevict([k[3] for k in keys])  # one exchange

    # -- lifecycle: server-side refcounts + leases (atomic on its loop) ------
    def incref(self, key: Key, n: int = 1) -> int:
        return self._client.incref(key[3], n)

    def decref(self, key: Key, n: int = 1) -> int:
        return self._client.decref(key[3], n)

    def refcount(self, key: Key) -> int:
        return self._client.refcount(key[3])

    def touch(self, key: Key, ttl: float | None) -> bool:
        return self._client.touch(key[3], ttl)

    def incref_batch(self, keys, n: int = 1) -> list[int]:
        return self._client.mincref([k[3] for k in keys], n)  # one exchange

    def decref_batch(self, keys, n: int = 1) -> list[int]:
        return self._client.mdecref([k[3] for k in keys], n)

    def touch_batch(self, keys, ttl: float | None) -> None:
        self._client.mtouch([k[3] for k in keys], ttl)

    def stats(self) -> dict[str, Any]:
        return self._client.stats()

    def config(self) -> dict[str, Any]:
        return {"host": self.host, "port": self.port}

    def close(self) -> None:
        self._client.close()
