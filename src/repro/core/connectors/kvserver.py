"""KVServerConnector — the Redis role (§4.1.2).

Connects to a standalone :mod:`repro.core.kv_tcp` server, which provides the
hybrid memory/disk semantics the paper gets from Redis: in-memory serving with
optional write-through persistence (``persist_dir``) surviving restarts.

The paper highlights that its RedisConnector is 31 lines on top of the
Connector protocol; this file is in the same spirit — the server itself lives
in ``kv_tcp.py``.
"""
from __future__ import annotations

import uuid
from typing import Any

from repro.core.connector import BaseConnector, Key, StreamItem
from repro.core.kv_tcp import KVClient
from repro.stream.broker import BrokerEvent


class KVServerConnector(BaseConnector):
    def __init__(self, host: str, port: int) -> None:
        self.host, self.port = host, int(port)
        self._client = KVClient(self.host, self.port)

    def put(self, blob) -> Key:
        object_id = uuid.uuid4().hex
        self._client.put(object_id, blob)  # gather-write, no join copy
        return ("kv", self.host, self.port, object_id)

    def put_batch(self, blobs) -> list[Key]:
        # ONE mput2 exchange: every frame's segments stream raw after the
        # header — Frames never touch msgpack, nothing is joined
        ids = [uuid.uuid4().hex for _ in blobs]
        self._client.mput(ids, blobs)
        return [("kv", self.host, self.port, i) for i in ids]

    def get(self, key: Key):
        return self._client.get(key[3])

    def get_batch(self, keys) -> list[bytes | None]:
        if not keys:
            return []
        # ONE mget2 exchange, received into one preallocated buffer
        return self._client.mget([k[3] for k in keys])

    def exists(self, key: Key) -> bool:
        return self._client.exists(key[3])

    def exists_batch(self, keys) -> list[bool]:
        return self._client.mexists([k[3] for k in keys])  # one exchange

    def evict(self, key: Key) -> None:
        self._client.evict(key[3])

    def evict_batch(self, keys) -> None:
        self._client.mevict([k[3] for k in keys])  # one exchange

    # -- futures: reserved keys + server-parked wait -------------------------
    def reserve(self) -> Key:
        return ("kv", self.host, self.port, uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._client.put(key[3], blob)   # the put wakes parked waiters

    def wait(self, key: Key, timeout: float = 60.0):
        # parks INSIDE the server: released by the producer's put even from
        # another connection/process, no polling
        return self._client.wait(key[3], timeout)

    # -- streams: server-side topics (one owning server per store) -----------
    def stream_append(self, topic: str, blob, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        return self._client.stream_append(topic, blob, ttl, meta=meta,
                                          timeout=timeout)

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    location: str | None = None) -> StreamItem:
        it = self._client.stream_next(topic, seq, timeout)
        return StreamItem(seq, it["data"], it["available"], it["end"])

    def stream_fetch(self, topic: str, seqs,
                     location: str | None = None) -> list:
        return self._client.stream_fetch(topic, seqs)

    def stream_close(self, topic: str, location: str | None = None) -> None:
        self._client.stream_close(topic)

    # -- pub/sub consumer groups: state lives in the server ------------------
    def stream_subscribe(self, topic: str, group: str, start: str = "new",
                         filter: dict | None = None,  # noqa: A002
                         location: str | None = None) -> dict:
        return self._client.stream_sub(topic, group, start, filter)

    def stream_unsubscribe(self, topic: str, group: str,
                           location: str | None = None) -> None:
        self._client.stream_unsub(topic, group)

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True,
                    location: str | None = None) -> BrokerEvent:
        it = self._client.stream_take(topic, group, timeout, payload)
        if it["end"]:
            return BrokerEvent(-1, None, {}, end=True)
        return BrokerEvent(int(it["seq"]), it["data"], it["meta"])

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True,
                          location: str | None = None) -> list[BrokerEvent]:
        return [BrokerEvent(it["seq"], it["data"], it["meta"])
                for it in self._client.stream_take_batch(topic, group, n,
                                                         payload)]

    def stream_ack(self, topic: str, group: str, seqs,
                   location: str | None = None) -> int:
        return self._client.stream_ack(topic, group, seqs)

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None,
                       location: str | None = None) -> int:
        return self._client.stream_requeue(topic, group, seqs,
                                           reason=reason)

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None,
                     location: str | None = None) -> None:
        self._client.stream_limit(topic, limit,
                                  max_deliveries=max_deliveries)

    def stream_stat(self, topic: str,
                    location: str | None = None) -> dict:
        return self._client.stream_stat(topic)

    # -- lifecycle: server-side refcounts + leases (atomic on its loop) ------
    def incref(self, key: Key, n: int = 1) -> int:
        return self._client.incref(key[3], n)

    def decref(self, key: Key, n: int = 1) -> int:
        return self._client.decref(key[3], n)

    def refcount(self, key: Key) -> int:
        return self._client.refcount(key[3])

    def ref_snapshot(self) -> dict[str, int]:
        """Server's full refcount table (sanitizer cross-check)."""
        return self._client.refsnap()

    def touch(self, key: Key, ttl: float | None) -> bool:
        return self._client.touch(key[3], ttl)

    def incref_batch(self, keys, n: int = 1) -> list[int]:
        return self._client.mincref([k[3] for k in keys], n)  # one exchange

    def decref_batch(self, keys, n: int = 1) -> list[int]:
        return self._client.mdecref([k[3] for k in keys], n)

    def touch_batch(self, keys, ttl: float | None) -> None:
        self._client.mtouch([k[3] for k in keys], ttl)

    def stats(self) -> dict[str, Any]:
        st = self._client.stats()
        # client-side resilience counters ride along with the server's
        st["n_reconnects"] = self._client.n_reconnects
        st["n_retries"] = self._client.n_retries
        return st

    def config(self) -> dict[str, Any]:
        return {"host": self.host, "port": self.port}

    def close(self) -> None:
        self._client.close()
