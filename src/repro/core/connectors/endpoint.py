"""EndpointConnector — client of PS-endpoints (§4.2.2).

Keys are ``("ep", object_id, endpoint_uuid)``.  The connector always talks to
its *local* endpoint; if the key's endpoint_uuid differs, the local endpoint
forwards the request over a peer channel (established via the relay server).

Which endpoint is "local" is site-dependent, so ``config()`` deliberately does
NOT pin an address: it records the name of an environment variable
(``PSJ_ENDPOINT`` by default, format ``host:port``) consulted at construction
time on the consuming process — the analog of the paper's hostname-regex →
endpoint mapping.  An explicit ``address`` overrides for single-site use.
"""
from __future__ import annotations

import os
import uuid as uuid_mod
from typing import Any

from repro.core.connector import BaseConnector, Key, StreamItem, group_indices
from repro.core.kv_tcp import MAX_FRAME, KVClient, _chain, stream_item_key
from repro.core.serialize import as_segments, frame_nbytes
from repro.stream.broker import BrokerEvent


class EndpointConnector(BaseConnector):
    def __init__(self, address: str | None = None,
                 env: str = "PSJ_ENDPOINT") -> None:
        self.env = env
        self.address = address
        addr = address or os.environ.get(env)
        if not addr:
            raise RuntimeError(
                f"no local PS-endpoint: pass address= or set ${env}")
        host, port = addr.rsplit(":", 1)
        # the endpoint speaks the same seq-tagged pipelined protocol as
        # kv_tcp, so any number of requests share the connection in flight
        self._client = KVClient(host, int(port))
        resp = self._client.request({"op": "uuid"})
        self.endpoint_uuid: str = resp["data"]

    def _put_msg(self, blob) -> tuple[str, dict, list]:
        # puts always target the local endpoint; the payload streams raw
        # after the header (put2), so multi-segment frames are gather-
        # written with no join or msgpack copy
        nbytes = frame_nbytes(blob)
        if nbytes > MAX_FRAME:
            # fail before streaming gigabytes the endpoint will reject
            raise ValueError(f"payload too large: {nbytes} > {MAX_FRAME}")
        object_id = uuid_mod.uuid4().hex
        msg = {"op": "put2", "object_id": object_id, "nbytes": nbytes}
        return object_id, msg, as_segments(blob)

    def put(self, blob) -> Key:
        object_id, msg, segments = self._put_msg(blob)
        resp = self._client.request(msg, payload=segments)
        if not resp["ok"]:
            raise RuntimeError(resp.get("error"))
        return ("ep", object_id, self.endpoint_uuid)

    def put_batch(self, blobs) -> list[Key]:
        # ONE mput2 exchange: all frame segments stream back to back
        ids = [uuid_mod.uuid4().hex for _ in blobs]
        self._client.mput(ids, blobs)
        return [("ep", i, self.endpoint_uuid) for i in ids]

    @staticmethod
    def _get_data(resp: dict):
        if not resp["ok"]:
            raise ConnectionError(resp.get("error"))
        return resp.get("data")

    def get(self, key: Key):
        # get2: the payload comes back out of band into a preallocated
        # buffer (remote keys are forwarded over the peer channel first)
        resp = self._client.request({"op": "get2", "object_id": key[1],
                                     "endpoint_id": key[2]})
        return self._get_data(resp)

    def get_batch(self, keys) -> list:
        # group by owning endpoint: ONE mget2 exchange per endpoint, the
        # groups pipelined concurrently (remote groups are forwarded over
        # the peer channel by our local endpoint)
        out: list = [None] * len(keys)
        futs = []
        for ep_uuid, idxs in group_indices(keys, 2).items():
            futs.append((idxs, self._client.submit(
                {"op": "mget2", "object_ids": [keys[i][1] for i in idxs],
                 "endpoint_id": ep_uuid})))
        for idxs, fut in futs:
            datas = self._get_data(fut.result(self._client.timeout))
            for i, d in zip(idxs, datas):
                out[i] = d
        return out

    def get_async(self, key: Key):
        return _chain(self._client.submit({"op": "get2", "object_id": key[1],
                                           "endpoint_id": key[2]}),
                      self._get_data)

    def exists(self, key: Key) -> bool:
        resp = self._client.request({"op": "exists", "object_id": key[1],
                                     "endpoint_id": key[2]})
        return bool(resp.get("data"))

    def exists_batch(self, keys) -> list[bool]:
        # one mexists exchange per owning endpoint, pipelined
        out = [False] * len(keys)
        futs = []
        for ep_uuid, idxs in group_indices(keys, 2).items():
            futs.append((idxs, self._client.submit(
                {"op": "mexists",
                 "object_ids": [keys[i][1] for i in idxs],
                 "endpoint_id": ep_uuid})))
        for idxs, fut in futs:
            flags = self._get_data(fut.result(self._client.timeout)) or []
            for i, flag in zip(idxs, flags):
                out[i] = bool(flag)
        return out

    def evict(self, key: Key) -> None:
        self._client.request({"op": "evict", "object_id": key[1],
                              "endpoint_id": key[2]})

    def evict_batch(self, keys) -> None:
        futs = [self._client.submit(
            {"op": "mevict", "object_ids": [keys[i][1] for i in idxs],
             "endpoint_id": ep_uuid})
            for ep_uuid, idxs in group_indices(keys, 2).items()]
        for f in futs:
            resp = f.result(self._client.timeout)
            if not resp.get("ok"):
                raise ConnectionError(resp.get("error"))

    # -- futures: reserved keys; wait parks on the OWNING endpoint -----------
    def reserve(self) -> Key:
        return ("ep", uuid_mod.uuid4().hex, self.endpoint_uuid)

    def put_to(self, key: Key, blob) -> None:
        if key[2] != self.endpoint_uuid:
            # puts are always local: producing into a key minted at another
            # site would store bytes its consumers will never look for
            raise ValueError(
                f"put_to of key owned by endpoint {key[2]} via {self.endpoint_uuid}")
        nbytes = frame_nbytes(blob)
        if nbytes > MAX_FRAME:
            raise ValueError(f"payload too large: {nbytes} > {MAX_FRAME}")
        resp = self._client.request(
            {"op": "put2", "object_id": key[1], "nbytes": nbytes},
            payload=as_segments(blob))
        if not resp["ok"]:
            raise RuntimeError(resp.get("error"))

    def wait(self, key: Key, timeout: float = 60.0):
        """Parks on the key's OWNING endpoint — peer-forwarded when that is
        not the local one, so a consumer at site B blocks until the
        producer at site A lands the put."""
        resp = self._client.request(
            {"op": "wait", "object_id": key[1], "endpoint_id": key[2],
             "timeout": timeout},
            timeout=timeout + 60.0)
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))
        return resp.get("data")

    # -- streams: topics live on the PRODUCER's endpoint ---------------------
    supports_location = True

    def stream_append(self, topic: str, blob, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        # ``timeout`` is accepted for interface parity but unused:
        # endpoints do not park appends on s_limit bounds (backpressure is
        # a KV-broker / LocalBroker feature — a parked append would stall
        # the endpoint's single-threaded peer loop)
        nbytes = frame_nbytes(blob)
        if nbytes > MAX_FRAME:
            raise ValueError(f"payload too large: {nbytes} > {MAX_FRAME}")
        msg = {"op": "s_append", "topic": topic, "nbytes": nbytes}
        if ttl is not None:
            msg["ttl"] = ttl
        if meta:
            msg["meta"] = meta
        # not idempotent: a reconnect-retry could append the item twice
        resp = self._client.request(msg, payload=as_segments(blob),
                                    retry=False)
        if not resp.get("ok"):
            raise RuntimeError(resp.get("error"))
        return int(resp["data"])

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    location: str | None = None) -> StreamItem:
        """``location`` is the producing endpoint's uuid (default: local);
        remote topics are peer-forwarded and park at the producer."""
        # not retried: serving the item consumes it (decref/evict) on the
        # owning endpoint, so a reconnect-retry would find it missing
        resp = self._client.request(
            {"op": "s_next", "topic": topic, "i": int(seq),
             "timeout": timeout,
             "endpoint_id": location or self.endpoint_uuid},
            timeout=timeout + 60.0, retry=False)
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))
        return StreamItem(int(seq), resp.get("data"),
                          int(resp.get("available", 0)),
                          bool(resp.get("end")))

    def stream_fetch(self, topic: str, seqs,
                     location: str | None = None) -> list:
        """Prefetch path: ONE forwarded mget for the blobs + ONE mdecref
        marking them consumed on the owning endpoint."""
        oids = [stream_item_key(topic, int(s)) for s in seqs]
        if not oids:
            return []
        ep = location or self.endpoint_uuid
        resp = self._client.request({"op": "mget2", "object_ids": oids,
                                     "endpoint_id": ep})
        blobs = self._get_data(resp)
        self._client.request({"op": "mdecref", "object_ids": oids,
                              "endpoint_id": ep})
        return blobs

    def stream_close(self, topic: str, location: str | None = None) -> None:
        resp = self._client.request(
            {"op": "s_close", "topic": topic,
             "endpoint_id": location or self.endpoint_uuid})
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))

    # -- pub/sub consumer groups: state on the PRODUCING endpoint, ops
    # peer-forwarded when ``location`` names a remote one ---------------------
    def _group_op(self, msg: dict, location: str | None):
        msg["endpoint_id"] = location or self.endpoint_uuid
        resp = self._client.request(msg)
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))
        return resp.get("data")

    def stream_subscribe(self, topic: str, group: str, start: str = "new",
                         filter: dict | None = None,  # noqa: A002
                         location: str | None = None) -> dict:
        msg = {"op": "s_sub", "topic": topic, "group": group,
               "start": start}
        if filter:
            msg["filter"] = filter
        return self._group_op(msg, location)

    def stream_unsubscribe(self, topic: str, group: str,
                           location: str | None = None) -> None:
        self._group_op({"op": "s_unsub", "topic": topic, "group": group},
                       location)

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True,
                    location: str | None = None) -> BrokerEvent:
        # parks on the producing endpoint (peer-forwarded when remote);
        # delivery moves the event out of the group queue, so no retry
        resp = self._client.request(
            {"op": "s_next2", "topic": topic, "group": group,
             "timeout": timeout, "payload": payload,
             "endpoint_id": location or self.endpoint_uuid},
            timeout=timeout + 60.0, retry=False)
        if resp.get("timeout"):
            raise TimeoutError(resp.get("error"))
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))
        if resp.get("end"):
            return BrokerEvent(-1, None, {}, end=True)
        return BrokerEvent(int(resp["i"]), resp.get("data"),
                           resp.get("meta") or {})

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True,
                          location: str | None = None) -> list[BrokerEvent]:
        resp = self._client.request(
            {"op": "s_fetch", "topic": topic, "group": group, "n": int(n),
             "payload": payload,
             "endpoint_id": location or self.endpoint_uuid}, retry=False)
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))
        seqs = resp.get("seqs") or []
        metas = resp.get("metas") or [{}] * len(seqs)
        datas = resp.get("data") or [None] * len(seqs)
        return [BrokerEvent(int(s), d, m or {})
                for s, m, d in zip(seqs, metas, datas)]

    def stream_ack(self, topic: str, group: str, seqs,
                   location: str | None = None) -> int:
        return int(self._group_op(
            {"op": "s_ack", "topic": topic, "group": group,
             "seqs": [int(s) for s in seqs]}, location) or 0)

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None,
                       location: str | None = None) -> int:
        msg = {"op": "s_requeue", "topic": topic, "group": group,
               "seqs": [int(s) for s in seqs]}
        if reason:
            msg["reason"] = reason
        return int(self._group_op(msg, location) or 0)

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None,
                     location: str | None = None) -> None:
        # accepted for interface parity: bounds the topic's buffered
        # accounting server-side, but endpoint appends never park on it
        msg = {"op": "s_limit", "topic": topic, "limit": limit}
        if max_deliveries is not None:
            msg["max_deliveries"] = max_deliveries
        self._group_op(msg, location)

    def stream_stat(self, topic: str,
                    location: str | None = None) -> dict:
        return self._group_op({"op": "s_stat", "topic": topic}, location)

    # -- lifecycle: counts live on the OWNING endpoint (peer-forwarded) ------
    def _lifetime_op(self, op: str, key: Key, **extra):
        resp = self._client.request({"op": op, "object_id": key[1],
                                     "endpoint_id": key[2], **extra})
        if not resp.get("ok"):
            raise ConnectionError(resp.get("error"))
        return resp.get("data")

    def incref(self, key: Key, n: int = 1) -> int:
        return int(self._lifetime_op("incref", key, n=n))

    def decref(self, key: Key, n: int = 1) -> int:
        return int(self._lifetime_op("decref", key, n=n))

    def refcount(self, key: Key) -> int:
        return int(self._lifetime_op("refcount", key))

    def touch(self, key: Key, ttl: float | None) -> bool:
        return bool(self._lifetime_op("touch", key, ttl=ttl))

    def _lifetime_batch(self, op: str, keys, **extra) -> list:
        # one exchange per owning endpoint, pipelined concurrently
        out: list = [0] * len(keys)
        futs = []
        for ep_uuid, idxs in group_indices(keys, 2).items():
            futs.append((idxs, self._client.submit(
                {"op": op, "object_ids": [keys[i][1] for i in idxs],
                 "endpoint_id": ep_uuid, **extra})))
        for idxs, fut in futs:
            resp = fut.result(self._client.timeout)
            if not resp.get("ok"):
                raise ConnectionError(resp.get("error"))
            for i, c in zip(idxs, resp.get("data") or [0] * len(idxs)):
                out[i] = c
        return out

    def incref_batch(self, keys, n: int = 1) -> list[int]:
        return [int(c) for c in self._lifetime_batch("mincref", keys, n=n)]

    def decref_batch(self, keys, n: int = 1) -> list[int]:
        return [int(c) for c in self._lifetime_batch("mdecref", keys, n=n)]

    def touch_batch(self, keys, ttl: float | None) -> None:
        self._lifetime_batch("mtouch", keys, ttl=ttl)

    def config(self) -> dict[str, Any]:
        # no address: consumers bind to THEIR local endpoint via env
        return {"env": self.env, "address": None if os.environ.get(self.env)
                else self.address}

    def close(self) -> None:
        self._client.close()
