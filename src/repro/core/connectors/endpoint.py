"""EndpointConnector — client of PS-endpoints (§4.2.2).

Keys are ``("ep", object_id, endpoint_uuid)``.  The connector always talks to
its *local* endpoint; if the key's endpoint_uuid differs, the local endpoint
forwards the request over a peer channel (established via the relay server).

Which endpoint is "local" is site-dependent, so ``config()`` deliberately does
NOT pin an address: it records the name of an environment variable
(``PSJ_ENDPOINT`` by default, format ``host:port``) consulted at construction
time on the consuming process — the analog of the paper's hostname-regex →
endpoint mapping.  An explicit ``address`` overrides for single-site use.
"""
from __future__ import annotations

import os
import uuid as uuid_mod
from typing import Any

from repro.core.connector import BaseConnector, Key
from repro.core.kv_tcp import KVClient
from repro.core.serialize import join_frame


class EndpointConnector(BaseConnector):
    def __init__(self, address: str | None = None,
                 env: str = "PSJ_ENDPOINT") -> None:
        self.env = env
        self.address = address
        addr = address or os.environ.get(env)
        if not addr:
            raise RuntimeError(
                f"no local PS-endpoint: pass address= or set ${env}")
        host, port = addr.rsplit(":", 1)
        # the endpoint speaks the same framed protocol as kv_tcp
        self._client = KVClient(host, int(port))
        resp = self._client.request({"op": "uuid"})
        self.endpoint_uuid: str = resp["data"]

    def put(self, blob) -> Key:
        object_id = uuid_mod.uuid4().hex
        # the endpoint protocol embeds payloads in the msgpack frame (they
        # may be forwarded over peer channels), so multi-segment frames pay
        # one join copy here
        resp = self._client.request({"op": "put", "object_id": object_id,
                                     "data": join_frame(blob),
                                     "endpoint_id": self.endpoint_uuid})
        if not resp["ok"]:
            raise RuntimeError(resp.get("error"))
        return ("ep", object_id, self.endpoint_uuid)

    def get(self, key: Key) -> bytes | None:
        resp = self._client.request({"op": "get", "object_id": key[1],
                                     "endpoint_id": key[2]})
        if not resp["ok"]:
            raise ConnectionError(resp.get("error"))
        return resp.get("data")

    def exists(self, key: Key) -> bool:
        resp = self._client.request({"op": "exists", "object_id": key[1],
                                     "endpoint_id": key[2]})
        return bool(resp.get("data"))

    def evict(self, key: Key) -> None:
        self._client.request({"op": "evict", "object_id": key[1],
                              "endpoint_id": key[2]})

    def config(self) -> dict[str, Any]:
        # no address: consumers bind to THEIR local endpoint via env
        return {"env": self.env, "address": None if os.environ.get(self.env)
                else self.address}

    def close(self) -> None:
        self._client.close()
