"""SocketConnector — spawned per-node TCP store servers (§4.1.3 ZMQ role).

"When one of these connectors is initialized for the first time in a process,
it spawns a process that acts as the storage server for that node" — the
discovery directory holds one address file per logical node; the first
connector to grab the lock spawns the server, later connectors (any process
on the "node") connect to it.  The store is elastic: proxies carry the
discovery dir, so new nodes spin up their own servers on first use.
"""
from __future__ import annotations

import os
import uuid
from pathlib import Path
from typing import Any

from repro.core.connector import BaseConnector, Key, StreamItem, group_indices
from repro.core.kv_tcp import KVClient, spawn_server
from repro.stream.broker import BrokerEvent


class SocketConnector(BaseConnector):
    def __init__(self, discovery_dir: str, node_id: str = "node0") -> None:
        self.discovery_dir = str(discovery_dir)
        self.node_id = node_id
        Path(discovery_dir).mkdir(parents=True, exist_ok=True)
        self._client = self._attach_or_spawn()

    # -- server lifecycle ----------------------------------------------------
    def _addr_file(self) -> Path:
        return Path(self.discovery_dir) / f"{self.node_id}.addr"

    def _attach_or_spawn(self) -> KVClient:
        addr = self._addr_file()
        lock = Path(self.discovery_dir) / f"{self.node_id}.lock"
        for _ in range(3):
            if addr.exists():
                host, port, _pid = addr.read_text().split(":")
                client = KVClient(host, int(port))
                if client.ping():
                    return client
                addr.unlink(missing_ok=True)  # stale server
            # race to spawn: O_EXCL lock file
            try:
                fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.close(fd)
            except FileExistsError:
                import time

                time.sleep(0.1)
                continue
            try:
                host, port, _pid = spawn_server(ready_file=str(addr))
                return KVClient(host, port)
            finally:
                lock.unlink(missing_ok=True)
        raise RuntimeError("could not attach to or spawn socket store server")

    # -- Connector ops --------------------------------------------------------
    def put(self, blob) -> Key:
        object_id = uuid.uuid4().hex
        self._client.put(object_id, blob)  # gather-write, no join copy
        return ("sock", self.discovery_dir, self.node_id, object_id)

    def put_batch(self, blobs) -> list[Key]:
        # ONE mput2 exchange: frame segments stream raw, no join copies
        keys = [uuid.uuid4().hex for _ in blobs]
        self._client.mput(keys, blobs)
        return [("sock", self.discovery_dir, self.node_id, k) for k in keys]

    def get(self, key: Key):
        return self._client_for(key).get(key[3])

    def get_batch(self, keys) -> list[bytes | None]:
        if not keys:
            return []
        # one mget2 exchange per node, all nodes pipelined concurrently
        out: list[bytes | None] = [None] * len(keys)
        futs = []
        for node, idxs in group_indices(keys, 2).items():
            client = self._client_for(keys[idxs[0]])
            futs.append((idxs, client.mget_async(
                [keys[i][3] for i in idxs]), client))
        for idxs, fut, client in futs:
            for i, blob in zip(idxs, fut.result(client.timeout)):
                out[i] = blob
        return out

    def exists(self, key: Key) -> bool:
        return self._client_for(key).exists(key[3])

    def exists_batch(self, keys) -> list[bool]:
        out = [False] * len(keys)
        for node, idxs in group_indices(keys, 2).items():
            client = self._client_for(keys[idxs[0]])
            flags = client.mexists([keys[i][3] for i in idxs])
            for i, flag in zip(idxs, flags):
                out[i] = flag
        return out

    def evict(self, key: Key) -> None:
        self._client_for(key).evict(key[3])

    def evict_batch(self, keys) -> None:
        for node, idxs in group_indices(keys, 2).items():
            client = self._client_for(keys[idxs[0]])
            client.mevict([keys[i][3] for i in idxs])

    # -- futures: reserved keys + server-parked wait -------------------------
    def reserve(self) -> Key:
        return ("sock", self.discovery_dir, self.node_id, uuid.uuid4().hex)

    def put_to(self, key: Key, blob) -> None:
        self._client_for(key).put(key[3], blob)

    def wait(self, key: Key, timeout: float = 60.0):
        # parks inside the OWNING node's server (waiters released by the
        # producer's put on any connection to that node)
        return self._client_for(key).wait(key[3], timeout)

    # -- streams: topics live on the PRODUCING node's server; a consumer on
    # another node passes that node's id as ``location`` ---------------------
    supports_location = True

    def _stream_client(self, location: str | None) -> KVClient:
        if location is None or location == self.node_id:
            return self._client
        addr = Path(self.discovery_dir) / f"{location}.addr"
        host, port, _pid = addr.read_text().split(":")
        return KVClient(host, int(port))

    def stream_append(self, topic: str, blob, ttl: float | None = None,
                      meta: dict | None = None,
                      timeout: float | None = None) -> int:
        return self._client.stream_append(topic, blob, ttl, meta=meta,
                                          timeout=timeout)

    def stream_next(self, topic: str, seq: int, timeout: float = 60.0,
                    location: str | None = None) -> StreamItem:
        it = self._stream_client(location).stream_next(topic, seq, timeout)
        return StreamItem(seq, it["data"], it["available"], it["end"])

    def stream_fetch(self, topic: str, seqs,
                     location: str | None = None) -> list:
        return self._stream_client(location).stream_fetch(topic, seqs)

    def stream_close(self, topic: str, location: str | None = None) -> None:
        self._stream_client(location).stream_close(topic)

    # -- pub/sub consumer groups: state on the producing node's server -------
    def stream_subscribe(self, topic: str, group: str, start: str = "new",
                         filter: dict | None = None,  # noqa: A002
                         location: str | None = None) -> dict:
        return self._stream_client(location).stream_sub(topic, group,
                                                        start, filter)

    def stream_unsubscribe(self, topic: str, group: str,
                           location: str | None = None) -> None:
        self._stream_client(location).stream_unsub(topic, group)

    def stream_take(self, topic: str, group: str, timeout: float = 60.0,
                    payload: bool = True,
                    location: str | None = None) -> BrokerEvent:
        it = self._stream_client(location).stream_take(topic, group,
                                                       timeout, payload)
        if it["end"]:
            return BrokerEvent(-1, None, {}, end=True)
        return BrokerEvent(int(it["seq"]), it["data"], it["meta"])

    def stream_take_batch(self, topic: str, group: str, n: int,
                          payload: bool = True,
                          location: str | None = None) -> list[BrokerEvent]:
        items = self._stream_client(location).stream_take_batch(
            topic, group, n, payload)
        return [BrokerEvent(it["seq"], it["data"], it["meta"])
                for it in items]

    def stream_ack(self, topic: str, group: str, seqs,
                   location: str | None = None) -> int:
        return self._stream_client(location).stream_ack(topic, group, seqs)

    def stream_requeue(self, topic: str, group: str, seqs,
                       reason: str | None = None,
                       location: str | None = None) -> int:
        return self._stream_client(location).stream_requeue(
            topic, group, seqs, reason=reason)

    def stream_limit(self, topic: str, limit: int | None,
                     max_deliveries: int | None = None,
                     location: str | None = None) -> None:
        self._stream_client(location).stream_limit(
            topic, limit, max_deliveries=max_deliveries)

    def stream_stat(self, topic: str,
                    location: str | None = None) -> dict:
        return self._stream_client(location).stream_stat(topic)

    # -- lifecycle: refcounts live on the owning node's server ---------------
    def incref(self, key: Key, n: int = 1) -> int:
        return self._client_for(key).incref(key[3], n)

    def decref(self, key: Key, n: int = 1) -> int:
        return self._client_for(key).decref(key[3], n)

    def refcount(self, key: Key) -> int:
        return self._client_for(key).refcount(key[3])

    def touch(self, key: Key, ttl: float | None) -> bool:
        return self._client_for(key).touch(key[3], ttl)

    def _lifetime_batch(self, keys, method: str, arg) -> list[int]:
        out = [0] * len(keys)
        for node, idxs in group_indices(keys, 2).items():
            client = self._client_for(keys[idxs[0]])
            counts = getattr(client, method)(
                [keys[i][3] for i in idxs], arg)
            for i, c in zip(idxs, counts or [0] * len(idxs)):
                out[i] = c
        return out

    def incref_batch(self, keys, n: int = 1) -> list[int]:
        return self._lifetime_batch(keys, "mincref", n)

    def decref_batch(self, keys, n: int = 1) -> list[int]:
        return self._lifetime_batch(keys, "mdecref", n)

    def touch_batch(self, keys, ttl: float | None) -> None:
        self._lifetime_batch(keys, "mtouch", ttl)

    def stats(self) -> dict:
        return self._client.stats()

    def _client_for(self, key: Key) -> KVClient:
        if key[2] == self.node_id:
            return self._client
        # remote node on the same fabric: dial its published address
        addr = Path(key[1]) / f"{key[2]}.addr"
        host, port, _pid = addr.read_text().split(":")
        return KVClient(host, int(port))

    def config(self) -> dict[str, Any]:
        return {"discovery_dir": self.discovery_dir, "node_id": self.node_id}

    def close(self) -> None:
        self._client.close()

    def shutdown_server(self) -> None:
        self._client.shutdown_server()
        self._addr_file().unlink(missing_ok=True)
