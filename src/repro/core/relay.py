"""Relay (signaling) server for PS-endpoint peering (paper Fig 4).

Endpoints register over a persistent TCP connection; the relay brokers the
offer/answer exchange that introduces two endpoints to each other.  In the
paper this carries WebRTC SDP + ICE candidates for UDP hole punching; on a
single host the "session description" degenerates to the peer's listening
address — which is exactly the information hole punching exists to establish.
The message flow (offer -> forward -> answer -> forward) is reproduced 1:1.

Hosting requirements are minimal (paper §4.2.2): the relay only moves O(KB)
introduction messages, never object data.
"""
from __future__ import annotations

import argparse
import asyncio
import os
import struct
import uuid as uuid_mod
from pathlib import Path

import msgpack

_LEN = struct.Struct(">I")


async def _read(reader: asyncio.StreamReader) -> dict | None:
    try:
        header = await reader.readexactly(4)
        (length,) = _LEN.unpack(header)
        body = await reader.readexactly(length)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    return msgpack.unpackb(body, raw=False)


def _frame(msg: dict) -> bytes:
    body = msgpack.packb(msg, use_bin_type=True)
    return _LEN.pack(len(body)) + body


class RelayServer:
    def __init__(self) -> None:
        # uuid -> (writer, metadata)
        self.endpoints: dict[str, tuple[asyncio.StreamWriter, dict]] = {}
        self._shutdown = asyncio.Event()

    async def handle(self, reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        registered: str | None = None
        try:
            while True:
                msg = await _read(reader)
                if msg is None:
                    break
                mtype = msg.get("type")
                if mtype == "register":
                    # assign a UUID if the endpoint doesn't have one yet
                    ep_uuid = msg.get("uuid") or uuid_mod.uuid4().hex
                    registered = ep_uuid
                    self.endpoints[ep_uuid] = (writer, msg.get("meta", {}))
                    writer.write(_frame({"type": "registered", "uuid": ep_uuid}))
                    await writer.drain()
                elif mtype in ("offer", "answer"):
                    # forward the session description to the target endpoint
                    target = msg.get("target")
                    entry = self.endpoints.get(target)
                    if entry is None:
                        writer.write(_frame({
                            "type": "error", "rid": msg.get("rid"),
                            "error": f"unknown endpoint {target}",
                        }))
                        await writer.drain()
                    else:
                        fwd = dict(msg)
                        fwd["source"] = registered
                        entry[0].write(_frame(fwd))
                        await entry[0].drain()
                elif mtype == "list":
                    writer.write(_frame({
                        "type": "endpoints", "rid": msg.get("rid"),
                        "uuids": list(self.endpoints),
                    }))
                    await writer.drain()
                elif mtype == "shutdown":
                    self._shutdown.set()
                    break
        finally:
            if registered and registered in self.endpoints:
                if self.endpoints[registered][0] is writer:
                    del self.endpoints[registered]
            writer.close()


async def serve(host: str, port: int, ready_file: str | None) -> None:
    relay = RelayServer()
    server = await asyncio.start_server(relay.handle, host, port)
    actual = server.sockets[0].getsockname()[1]
    if ready_file:
        tmp = Path(ready_file + ".tmp")
        tmp.write_text(f"{host}:{actual}:{os.getpid()}")
        tmp.replace(ready_file)
    async with server:
        await relay._shutdown.wait()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--ready-file", default=None)
    args = ap.parse_args()
    asyncio.run(serve(args.host, args.port, args.ready_file))


if __name__ == "__main__":
    main()
