"""Slab-arena data plane tests: allocator mechanics, multi-process
put/get stress over one registry dir, exhaustion -> overflow growth,
evict-while-a-view-is-exported, and refcount/lease integration on slots."""
import multiprocessing as mp
import os
import time

import numpy as np
import pytest

from repro.core import deserialize, serialize
from repro.core.arena import FREE, Arena, size_class
from repro.core.connectors.shm import SharedMemoryConnector


# ---------------------------------------------------------------------------
# allocator mechanics
# ---------------------------------------------------------------------------
def test_size_classes():
    assert size_class(1) == 10               # floor: 1 KiB chunks
    assert size_class(1024) == 10
    assert size_class(1025) == 11
    assert size_class(10_000) == 14          # 16 KiB chunk
    assert 1 << size_class(10_000) >= 10_000


def test_arena_alloc_commit_read_free(tmp_path):
    a = Arena("psja_test_alloc", create=True, size=1 << 20, nslots=32)
    try:
        slot = a.alloc(5)
        assert a.read(slot, 0) is None       # WRITING: invisible
        a.slot_view(slot)[:] = b"hello"
        gen = a.commit(slot)
        assert bytes(a.read(slot, gen)) == b"hello"
        assert a.read(slot, gen + 1) is None  # wrong generation
        assert a.free(slot, gen)
        assert a.read(slot, gen) is None      # freed
        # slot + chunk are recycled under a NEW generation
        slot2 = a.alloc(5)
        a.slot_view(slot2)[:] = b"world"
        gen2 = a.commit(slot2)
        assert slot2 == slot and gen2 == gen + 1
        assert bytes(a.read(slot2, gen2)) == b"world"
        assert a.read(slot, gen) is None      # stale key stays dead
    finally:
        a.close()
        a.unlink()


def test_request_free_reclaimed_lazily():
    a = Arena("psja_test_reqfree", create=True, size=1 << 20, nslots=8)
    try:
        slot = a.alloc(100)
        a.slot_view(slot)[:3] = b"abc"
        gen = a.commit(slot)
        a.request_free(slot, gen)            # what a non-owner eviction does
        assert a.read(slot, gen) is None
        assert a.reclaim() == 1
        assert a._entry(slot)[0] == FREE
    finally:
        a.close()
        a.unlink()


def test_connector_roundtrip_and_key_shape(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        key = conn.put(b"payload")
        assert key[0] == "shm"
        arena, slot, gen = key[2].rsplit(".", 2)
        assert arena.startswith("psja_")
        assert conn.get(key) == b"payload"
        conn.evict(key)
        assert conn.get(key) is None and not conn.exists(key)
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# exhaustion -> growth (fresh arena / dedicated overflow arena)
# ---------------------------------------------------------------------------
def test_arena_exhaustion_grows_new_arena(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"),
                                 arena_size=256 * 1024, nslots=16)
    try:
        blobs = [os.urandom(60 * 1024) for _ in range(12)]  # ~12x64K chunks
        keys = [conn.put(b) for b in blobs]
        assert conn._pool.stats()["n_owned_arenas"] >= 2
        for k, b in zip(keys, blobs):
            assert bytes(conn.get(k)) == b
    finally:
        conn.close()


def test_oversized_object_gets_overflow_arena(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"),
                                 arena_size=128 * 1024, nslots=16)
    try:
        big = os.urandom(1 << 20)            # 8x the arena size
        key = conn.put(big)
        assert bytes(conn.get(key)) == big
        assert conn._pool.stats()["n_owned_arenas"] >= 1
        conn.evict(key)
        assert not conn.exists(key)
    finally:
        conn.close()


def test_slot_reuse_bounds_arena_count(tmp_path):
    """put/evict churn must recycle chunks, not grow the pool."""
    conn = SharedMemoryConnector(str(tmp_path / "shm"),
                                 arena_size=256 * 1024, nslots=8)
    try:
        for i in range(200):
            k = conn.put(os.urandom(30 * 1024))
            assert conn.exists(k)
            conn.evict(k)
        assert conn._pool.stats()["n_owned_arenas"] == 1
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# zero-copy views: eviction + close while exported
# ---------------------------------------------------------------------------
def test_evict_while_view_exported(tmp_path):
    from repro.analysis import SanitizerError, enabled

    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        arr = np.arange(4096, dtype=np.float32)
        key = conn.put(serialize(arr))
        view = conn.get(key)
        out = deserialize(view)              # zero-copy array over the view
        np.testing.assert_array_equal(out, arr)
        if enabled():
            # the sanitizer turns this exact pattern into a hard error
            # naming the borrow site; dropping the view unblocks the evict
            with pytest.raises(SanitizerError, match="use-after-free-view"):
                conn.evict(key)
            del view
            conn.evict(key)
            assert not conn.exists(key)
        else:
            conn.evict(key)                  # while the view is exported
            assert not conn.exists(key)
            assert conn.get(key) is None
            assert view.nbytes > 0           # view stays VALID (no crash)...
    finally:
        conn.close()                         # ...even through close()


def test_ephemeral_resolve_owns_its_memory(tmp_path):
    """Regression (review): an evict=True proxy's resolve drops the key's
    last reference — the arena chunk is then recycled by the very next
    put.  The Store must detach (deep-copy) shm-borrowed results before
    the drop, or the resolved array silently mutates."""
    from repro.core import Store
    from repro.core.store import unregister_store

    store = Store("arena-ephemeral", SharedMemoryConnector(
        str(tmp_path / "shm")))
    try:
        arr = np.full(4096, 7, dtype=np.int64)
        p = store.proxy(arr, evict=True)
        resolved = np.asarray(+p)            # touch -> resolve + decref
        np.testing.assert_array_equal(resolved, arr)
        for i in range(8):                   # churn: recycle the chunk
            store.connector.put(serialize(np.full(4096, 9, dtype=np.int64)))
        np.testing.assert_array_equal(resolved, arr)   # still 7s, not 9s
    finally:
        store.close()
        unregister_store("arena-ephemeral")


def test_owned_proxy_release_keeps_resolved_data(tmp_path):
    """Same property through the OwnedProxy release path."""
    from repro.core import Store, extract, release
    from repro.core.store import unregister_store

    store = Store("arena-owned", SharedMemoryConnector(str(tmp_path / "shm")))
    try:
        arr = np.full(2048, 3, dtype=np.int64)
        p = store.owned_proxy(arr)
        resolved = np.asarray(extract(p))
        release(p)                           # last ref: slot freed
        store.connector.put(serialize(np.full(2048, 5, dtype=np.int64)))
        np.testing.assert_array_equal(resolved, arr)
    finally:
        store.close()
        unregister_store("arena-owned")


def test_view_contents_stable_until_evict(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        key = conn.put(b"A" * 1000)
        view = conn.get(key)
        assert bytes(view[:4]) == b"AAAA"
        # a second put must not touch the live slot
        conn.put(b"B" * 1000)
        assert bytes(view[:4]) == b"AAAA"
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# refcount / lease integration on slab slots
# ---------------------------------------------------------------------------
def test_refcount_on_slots(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        key = conn.put(b"shared-object")
        conn.incref(key, 2)
        assert conn.decref(key) == 1
        assert conn.exists(key)              # one reference left
        assert conn.decref(key) == 0         # last ref: slot freed
        assert not conn.exists(key)
        assert conn.get(key) is None
    finally:
        conn.close()


def test_lease_expiry_frees_slot(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        key = conn.put(b"leased")
        conn.incref(key)
        assert conn.touch(key, 0.05)         # 50 ms lease
        time.sleep(0.12)
        # the fallback table sweeps on the next lifecycle op
        assert conn.refcount(key) == 0
        assert not conn.exists(key)
    finally:
        conn.close()


def test_reserved_key_future_path(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        key = conn.reserve()
        assert not conn.exists(key)
        assert conn.get(key) is None
        conn.put_to(key, b"late data")
        assert conn.exists(key)
        assert bytes(conn.get(key)) == b"late data"
        # a second connector (fresh process analog) resolves the same
        # reserved id via the slot-table scan
        other = SharedMemoryConnector(**conn.config())
        try:
            assert bytes(other.get(key)) == b"late data"
        finally:
            other._pool._owned.clear()       # reader: never unlink
            other.close()
        conn.evict(key)
        assert not conn.exists(key)
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# orphan sweep (satellite: crashed-producer hygiene)
# ---------------------------------------------------------------------------
def test_startup_scan_drops_tmp_orphans_and_dead_markers(tmp_path):
    reg = tmp_path / "shm"
    reg.mkdir()
    (reg / ".deadbeef.tmp").write_text("{}")           # crashed mid-publish
    (reg / "psja_gone00000000.arena").write_text("1")  # segment never existed
    conn = SharedMemoryConnector(str(reg))
    try:
        assert not (reg / ".deadbeef.tmp").exists()
        assert not (reg / "psja_gone00000000.arena").exists()
    finally:
        conn.close()


def test_clear_sweeps_dead_owner_arenas(tmp_path):
    reg = str(tmp_path / "shm")
    ctx = mp.get_context("spawn")
    proc = ctx.Process(target=_producer_that_dies, args=(reg,))
    proc.start()
    proc.join(30)
    assert proc.exitcode == 0
    # the dead producer's arena + marker are still there (no cleanup ran)
    conn = SharedMemoryConnector(reg, clear=True)
    try:
        import glob

        assert glob.glob(os.path.join(reg, "*.arena")) == []
        # legacy sidecars are cleared too
        assert glob.glob(os.path.join(reg, "*.json")) == []
    finally:
        conn.close()


def _producer_that_dies(reg: str) -> None:
    conn = SharedMemoryConnector(reg)
    conn.put(b"leaked unless swept")
    # simulate a crash: neither close() nor atexit runs for the pool
    import atexit

    atexit.unregister(conn.close)
    conn._pool._owned.clear()


# ---------------------------------------------------------------------------
# multi-process stress: N producers x M consumers over one registry dir
# ---------------------------------------------------------------------------
def _stress_producer(reg: str, seed: int, n_items: int, q) -> None:
    conn = SharedMemoryConnector(reg, arena_size=4 * 1024 * 1024, nslots=256)
    rng = np.random.default_rng(seed)
    try:
        for i in range(n_items):
            size = int(rng.integers(1, 64)) * 1024
            arr = rng.standard_normal(size // 8)
            key = conn.put(serialize(arr))
            q.put((key, float(arr.sum())))
        q.put(None)                          # this producer is done
        time.sleep(1.5)   # keep arenas alive while consumers drain
    finally:
        conn.close()


def _stress_consumer(reg: str, q, done_q, n_producers: int) -> None:
    conn = SharedMemoryConnector(reg)
    try:
        n_done = 0
        n_ok = 0
        while n_done < n_producers:
            item = q.get(timeout=30)
            if item is None:
                n_done += 1
                continue
            key, checksum = item
            arr = deserialize(conn.get(key))
            assert abs(float(np.asarray(arr).sum()) - checksum) < 1e-6
            n_ok += 1
        done_q.put(n_ok)
    finally:
        conn._pool._owned.clear()            # reader: never unlink
        conn.close()


def test_multiprocess_producers_consumers(tmp_path):
    reg = str(tmp_path / "shm")
    ctx = mp.get_context("spawn")
    q: mp.Queue = ctx.Queue()
    done_q: mp.Queue = ctx.Queue()
    n_items = 25
    producers = [ctx.Process(target=_stress_producer,
                             args=(reg, 100 + i, n_items, q))
                 for i in range(2)]
    consumer = ctx.Process(target=_stress_consumer,
                           args=(reg, q, done_q, len(producers)))
    for p in producers:
        p.start()
    consumer.start()
    try:
        n_ok = done_q.get(timeout=60)
        assert n_ok == n_items * len(producers)
    finally:
        for p in producers:
            p.join(30)
        consumer.join(30)
        for p in [*producers, consumer]:
            if p.is_alive():  # pragma: no cover - hung worker
                p.terminate()
                pytest.fail("stress worker hung")
