"""Object lifecycle & ownership: refcounted keys, OwnedProxy/borrow
semantics, TTL leases — and the multi-consumer evict race they fix."""
import copy
import gc
import os
import pickle
import signal
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.core import (OwnedProxy, Store, borrow, clone, get_factory,
                        into_owned, is_proxy, is_resolved, release,
                        resolve_async, unregister_store)
from repro.core.connectors import (FileConnector, KVServerConnector,
                                   LocalMemoryConnector)
from repro.core.kv_tcp import KVClient, KVServer, spawn_server
from repro.core.multi import MultiConnector, Policy
from repro.core.proxy import Proxy, ProxyResolveError
from repro.core.store import StoreFactory


# ---------------------------------------------------------------------------
# server-level semantics (driving KVServer.handle directly)
# ---------------------------------------------------------------------------
def test_server_refcount_evicts_exactly_once():
    kv = KVServer()
    kv._put("k", b"x")
    assert kv.handle({"op": "incref", "key": "k"})["data"] == 1
    assert kv.handle({"op": "incref", "key": "k", "n": 2})["data"] == 3
    assert kv.handle({"op": "refcount", "key": "k"})["data"] == 3
    assert kv.handle({"op": "decref", "key": "k", "n": 2})["data"] == 1
    assert "k" in kv._data
    assert kv.handle({"op": "decref", "key": "k"})["data"] == 0
    assert "k" not in kv._data and "k" not in kv.lifetime.refs
    # further decrefs are harmless no-ops (nothing left to evict twice)
    assert kv.handle({"op": "decref", "key": "k"})["data"] == 0


def test_server_legacy_decref_without_incref_hard_evicts():
    kv = KVServer()
    kv._put("legacy", b"x")
    assert kv.handle({"op": "decref", "key": "legacy"})["data"] == 0
    assert "legacy" not in kv._data


def test_server_batched_lifecycle_ops():
    kv = KVServer()
    for k in ("a", "b"):
        kv._put(k, b"v")
    assert kv.handle({"op": "mincref", "keys": ["a", "b"]})["data"] == [1, 1]
    assert kv.handle({"op": "mdecref", "keys": ["a", "b"]})["data"] == [0, 0]
    assert not kv._data


def test_server_lease_expiry_lazy_sweep():
    kv = KVServer()
    kv._put("m", b"z")
    kv.handle({"op": "incref", "key": "m"})
    assert kv.handle({"op": "touch", "key": "m", "ttl": 0.05})["data"] is True
    time.sleep(KVServer.SWEEP_INTERVAL + 0.1)
    kv.handle({"op": "ping"})          # lazy sweep runs on any request
    assert "m" not in kv._data and "m" not in kv.lifetime.refs
    stats = kv.handle({"op": "stats"})["data"]
    assert stats["n_expired"] == 1
    assert stats["n_refcounted"] == 0 and stats["n_leases"] == 0


def test_server_touch_refresh_and_clear():
    kv = KVServer()
    kv._put("k", b"v")
    kv.handle({"op": "touch", "key": "k", "ttl": 30})
    assert "k" in kv.lifetime.leases
    kv.handle({"op": "touch", "key": "k", "ttl": None})   # clear the lease
    assert "k" not in kv.lifetime.leases
    assert kv.handle({"op": "touch", "key": "missing", "ttl": 1})["data"] \
        is False


# ---------------------------------------------------------------------------
# wire protocol (live server)
# ---------------------------------------------------------------------------
@pytest.fixture()
def kv(tmp_path):
    host, port, pid = spawn_server(ready_file=str(tmp_path / "kv.ready"))
    client = KVClient(host, port)
    yield client
    client.shutdown_server()
    client.close()
    try:
        os.kill(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        pass


def test_client_lifecycle_ops(kv):
    kv.put("k", b"payload")
    assert kv.incref("k") == 1
    assert kv.incref("k", 2) == 3
    assert kv.refcount("k") == 3
    assert kv.decref("k", 3) == 0
    assert not kv.exists("k")
    kv.mput(["a", "b"], [b"1", b"2"])
    assert kv.mincref(["a", "b"]) == [1, 1]
    assert kv.mdecref(["a", "b"]) == [0, 0]
    assert kv.mexists(["a", "b"]) == [False, False]


def test_idle_server_expires_leases(kv):
    """The periodic backstop sweeps even with no requests arriving."""
    kv.put("leased", b"v")
    assert kv.touch("leased", 0.2) is True
    time.sleep(1.2)                    # idle: no ops during the lease
    assert not kv.exists("leased")
    assert kv.stats()["n_expired"] >= 1


# ---------------------------------------------------------------------------
# the evict-race regression (ISSUE satellite 1 + acceptance criteria)
# ---------------------------------------------------------------------------
@pytest.fixture()
def kv_store(tmp_path):
    host, port, pid = spawn_server(ready_file=str(tmp_path / "kv.ready"))
    store = Store("own-t", KVServerConnector(host, port))
    yield store
    store.connector._client.shutdown_server()
    store.close()
    try:
        os.kill(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        pass


def test_two_sibling_evict_proxies_both_resolve(kv_store):
    """Regression: with fire-and-forget evict the second resolve raised
    LookupError; refcounted siblings both resolve, key dies after the last."""
    s = kv_store
    key = s.put({"v": 1})
    p1 = s.proxy_from_key(key, evict=True)
    p2 = s.proxy_from_key(key, evict=True)
    assert s.refcount(key) == 2
    assert p1["v"] == 1
    assert s.connector.exists(key), "first resolve must not evict"
    assert p2["v"] == 1
    assert not s.connector.exists(key), "last resolve evicts"


def test_sibling_evict_proxies_across_pickling(kv_store):
    s = kv_store
    s.cache.maxsize = 0                   # force connector round trips
    key = s.put([1, 2, 3])
    p1 = s.proxy_from_key(key, evict=True)
    wire = pickle.loads(pickle.dumps(p1))     # communicated sibling
    assert s.refcount(key) == 2
    assert wire[0] == 1
    assert s.connector.exists(key)
    assert p1[0] == 1
    assert not s.connector.exists(key)


def test_n_siblings_concurrent_threads_and_pickling(kv_store):
    """Acceptance: N>=3 siblings to one refcounted key, resolved
    concurrently across threads and across pickling — all succeed and the
    key is evicted exactly once after the last decref (server count)."""
    s = kv_store
    s.cache.maxsize = 0
    n = 4
    key = s.put({"w": list(range(100))})
    sibs = [s.proxy_from_key(key, evict=True) for _ in range(n)]
    wire = [pickle.loads(pickle.dumps(p)) for p in sibs]
    assert s.refcount(key) == 2 * n
    barrier = threading.Barrier(8)

    def consume(p):
        barrier.wait(timeout=10)
        return p["w"][5]

    with ThreadPoolExecutor(max_workers=2 * n) as pool:
        results = list(pool.map(consume, sibs + wire))
    assert results == [5] * 2 * n         # every consumer resolved
    assert s.refcount(key) == 0
    srv = s.stats()["connector"]
    assert srv["n_objects"] == 0, "key must be gone after the last decref"
    assert srv["n_refcounted"] == 0, "no leaked refcount entries"
    with pytest.raises(ProxyResolveError, match="not found"):
        _ = s.proxy_from_key(key)["w"]    # and it is really gone


def test_batch_evict_proxies_resolve_async_cleanup(kv_store):
    """proxy_batch(evict=True) siblings through the grouped async resolve
    path (_fetch_group) also decref instead of hard-evicting."""
    s = kv_store
    proxies = s.proxy_batch([{"i": i} for i in range(5)], evict=True)
    wire = pickle.loads(pickle.dumps(proxies))
    resolve_async(wire)
    assert [p["i"] for p in wire] == list(range(5))
    keys = [get_factory(p).key for p in proxies]
    assert [s.refcount(k) for k in keys] == [1] * 5   # originals still hold
    assert all(s.connector.exists(k) for k in keys)
    assert [p["i"] for p in proxies] == list(range(5))
    assert s.stats()["connector"]["n_objects"] == 0


# ---------------------------------------------------------------------------
# OwnedProxy / borrow / clone / into_owned
# ---------------------------------------------------------------------------
def test_owned_proxy_released_on_gc(kv_store):
    s = kv_store
    p = s.owned_proxy({"big": 1})
    key = get_factory(p).key
    assert p["big"] == 1
    assert s.connector.exists(key), "resolving an OwnedProxy never consumes"
    del p
    gc.collect()
    assert not s.connector.exists(key)


def test_owned_proxy_context_manager_and_idempotent_release(kv_store):
    s = kv_store
    with s.owned_proxy("ctx") as p:
        key = get_factory(p).key
        assert p == "ctx"
    assert not s.connector.exists(key)
    release(p)                            # second release is a no-op


def test_clone_is_a_co_owner(kv_store):
    s = kv_store
    p = s.owned_proxy([1])
    key = get_factory(p).key
    c = clone(p)
    assert s.refcount(key) == 2
    release(p)
    assert s.connector.exists(key)
    release(c)
    assert not s.connector.exists(key)


def test_pickling_owned_proxy_clones_a_reference(kv_store):
    s = kv_store
    p = s.owned_proxy("wire")
    key = get_factory(p).key
    wire = pickle.loads(pickle.dumps(p))
    assert type(wire) is OwnedProxy
    assert s.refcount(key) == 2
    release(p)
    assert wire == "wire"
    assert s.connector.exists(key)
    release(wire)
    assert not s.connector.exists(key)


def test_borrow_blocks_release_and_detaches_on_pickle(kv_store):
    s = kv_store
    owner = s.owned_proxy({"x": 9})
    key = get_factory(owner).key
    b = borrow(owner)
    assert b["x"] == 9                    # borrowed access does not consume
    assert s.refcount(key) == 1
    with pytest.raises(RuntimeError, match="borrow"):
        release(owner)
    wire = pickle.loads(pickle.dumps(b))  # a communicated borrow detaches
    assert wire["x"] == 9
    del b
    gc.collect()
    release(owner)
    assert not s.connector.exists(key)


def test_into_owned_moves_the_ephemeral_reference(kv_store):
    s = kv_store
    key = s.put("mv")
    e = s.proxy_from_key(key, evict=True)
    o = into_owned(e)
    assert type(o) is OwnedProxy
    assert s.refcount(key) == 1           # moved, not duplicated
    assert e == "mv"                      # original resolves w/o consuming
    assert s.connector.exists(key)
    release(o)
    assert not s.connector.exists(key)


def test_into_owned_on_plain_proxy_acquires(kv_store):
    s = kv_store
    p = s.proxy("plain")
    key = get_factory(p).key
    o = into_owned(p)
    assert s.refcount(key) == 1
    release(o)
    assert not s.connector.exists(key)


def test_store_lease_reaps_abandoned_key(kv_store):
    s = kv_store
    p = s.owned_proxy("leaky", ttl=0.2)
    key = get_factory(p).key
    assert s.refcount(key) == 1
    time.sleep(1.2)                       # holder "crashed": never releases
    assert not s.connector.exists(key)
    assert s.stats()["connector"]["n_expired"] >= 1


def test_is_proxy_and_transparency_of_owned_proxy(kv_store):
    p = kv_store.owned_proxy([1, 2, 3])
    assert is_proxy(p)
    assert isinstance(p, list)            # __class__ transparency holds
    assert len(p) == 3 and p + [4] == [1, 2, 3, 4]
    release(p)


# ---------------------------------------------------------------------------
# local-fallback lifecycle (non-KV connectors) + MultiConnector dispatch
# ---------------------------------------------------------------------------
def test_local_fallback_refcount_file_connector(tmp_path):
    s = Store("own-file", FileConnector(str(tmp_path / "f")))
    key = s.put("v")
    p1 = s.proxy_from_key(key, evict=True)
    p2 = s.proxy_from_key(key, evict=True)
    assert p1 == "v"
    assert s.connector.exists(key)
    assert p2 == "v"
    assert not s.connector.exists(key)


def test_local_fallback_decref_without_entry_never_evicts(tmp_path):
    """A process-local table must not evict on decref of an unknown key —
    the count may live with the creating process."""
    conn = FileConnector(str(tmp_path / "f"))
    key = conn.put(b"shared")
    assert conn.decref(key) == 0
    assert conn.exists(key), "data other processes may need must survive"


def test_local_fallback_lease(tmp_path):
    conn = LocalMemoryConnector()
    key = conn.put(b"x")
    conn.incref(key)
    conn.touch(key, 0.05)
    time.sleep(0.1)
    assert conn.refcount(key) == 0        # lazy sweep on lifecycle ops
    assert not conn.exists(key)


def test_multi_connector_dispatches_lifecycle(tmp_path):
    small = LocalMemoryConnector()
    big = FileConnector(str(tmp_path / "big"))
    multi = MultiConnector([(small, Policy(max_size=1000, priority=1)),
                            (big, Policy())])
    k_small = multi.put(b"s")
    k_big = multi.put(b"b" * 10_000)
    assert multi.incref_batch([k_small, k_big]) == [1, 1]
    assert multi.refcount(k_small) == 1
    assert multi.decref(k_small) == 0
    assert not multi.exists(k_small)
    assert multi.exists(k_big)
    assert multi.decref_batch([k_big]) == [0]
    assert not multi.exists(k_big)


# ---------------------------------------------------------------------------
# satellite regressions: stale exists, registration leak, copy semantics
# ---------------------------------------------------------------------------
def test_exists_consults_connector_not_stale_cache(tmp_path):
    """Satellite: a cached deserialization must not make exists() report
    True for a key another consumer already evicted on the channel."""
    s = Store("stale-t", FileConnector(str(tmp_path / "d")))
    key = s.put({"x": 1})
    assert s.get(key)["x"] == 1           # primes the local cache
    # another consumer (same channel, different Store) evicts the key
    other = FileConnector(str(tmp_path / "d"))
    other.evict(key)
    assert not s.exists(key)
    assert tuple(key) not in s.cache      # stale entry dropped on miss


def test_duplicate_store_config_build_closes_connector(monkeypatch):
    """Satellite: StoreConfig.build() on a duplicate name must not leak the
    connector it just constructed."""
    closed = []
    monkeypatch.setattr(LocalMemoryConnector, "close",
                        lambda self: closed.append(self.store_id))
    s = Store("dup-own", LocalMemoryConnector())
    cfg = s.config()
    with pytest.raises(ValueError, match="already registered"):
        cfg.build()
    assert len(closed) == 1, "freshly built connector must be closed"
    unregister_store("dup-own")


def test_copy_of_resolved_proxy_stays_resolved(tmp_path):
    s = Store("copy-t", FileConnector(str(tmp_path / "c")))
    p = s.proxy({"a": 1})
    assert p["a"] == 1                    # resolve
    cp = copy.copy(p)
    assert is_resolved(cp) and cp["a"] == 1
    dp = copy.deepcopy(p)
    assert is_resolved(dp) and dp["a"] == 1
    dp["a"] = 2                           # deep copy: independent target
    assert p["a"] == 1


def test_deepcopy_of_unresolved_evict_proxy_is_a_sibling(tmp_path):
    s = Store("copy-e", FileConnector(str(tmp_path / "c")))
    key = s.put("v")
    p = s.proxy_from_key(key, evict=True)
    dp = copy.deepcopy(p)                 # acquires its own reference
    assert not is_resolved(dp)
    assert s.connector.refcount(key) == 2
    assert p == "v" and s.connector.exists(key)
    assert dp == "v" and not s.connector.exists(key)


def test_resolved_evict_proxy_pickles_as_plain(kv_store):
    """A consumed ephemeral must not promise the wire copy a reference."""
    s = kv_store
    p = s.proxy("once", evict=True)
    assert p == "once"                    # consumes the only reference
    wire_factory = pickle.loads(pickle.dumps(get_factory(p)))
    assert wire_factory.evict is False


def test_released_owned_proxy_cannot_be_pickled(kv_store):
    p = kv_store.owned_proxy("done")
    release(p)
    with pytest.raises(RuntimeError, match="released"):
        pickle.dumps(p)


def test_released_owned_proxy_cannot_be_cloned(kv_store):
    """Cloning a released owner would put a phantom count on dead data."""
    s = kv_store
    p = s.owned_proxy("gone")
    key = get_factory(p).key
    release(p)
    with pytest.raises(RuntimeError, match="released or consumed"):
        clone(p)
    assert s.refcount(key) == 0           # no phantom reference appeared


def test_owned_proxy_deepcopy_is_independent(kv_store):
    s = kv_store
    p = s.owned_proxy({"a": 1})
    key = get_factory(p).key
    assert p["a"] == 1                    # resolve (populates the cache)
    dp = copy.deepcopy(p)
    assert s.refcount(key) == 2           # the deepcopy co-owns
    dp["a"] = 2
    assert p["a"] == 1, "deepcopy must not share the cached target"
    release(p)
    release(dp)
    assert not s.connector.exists(key)


def test_ephemeral_proxy_ttl_reaps_undelivered_sibling(kv_store):
    """An evict=True proxy pickled but never delivered (e.g. a payload-cap
    rejection after dumps) must not leak its key forever: the ttl lease is
    the backstop."""
    s = kv_store
    p = s.proxy("capped", evict=True, ttl=0.2)
    key = get_factory(p).key
    _ = pickle.dumps(p)                   # incref'd blob that is never sent
    assert s.refcount(key) == 2
    time.sleep(1.2)
    assert not s.connector.exists(key)
    assert s.refcount(key) == 0


def test_explicit_evict_clears_local_fallback_state(tmp_path):
    """Satellite-of-review: store.evict() on a local connector must drop
    refcount/lease state with the data, like the server-side _evict."""
    s = Store("evict-own", FileConnector(str(tmp_path / "f")))
    key = s.put("v")
    s.proxy_from_key(key, evict=True, ttl=60)   # count 1 + lease
    assert s.connector.refcount(key) == 1
    s.evict(key)                                # explicit override
    assert s.connector.refcount(key) == 0, "no live count on dead data"
    assert not s.connector.exists(key)


def test_failed_release_keeps_the_reference_armed(kv_store):
    """A release() rejected because borrows are alive must leave the
    reference droppable — a later release (or GC) still evicts."""
    s = kv_store
    owner = s.owned_proxy("armed")
    key = get_factory(owner).key
    b = borrow(owner)
    with pytest.raises(RuntimeError):
        release(owner)
    del b
    gc.collect()
    release(owner)                        # the reference was NOT consumed
    assert not s.connector.exists(key)
