"""Futures & streaming: communicate data before it exists.

Wire layer (wait/mwait parked ops, stream ops with refcount integration),
Store layer (ProxyFuture pre-data proxies, set_exception fan-out,
StreamProducer/ProxyStream), the PS-endpoint peer-forwarded wait path, and
the batch-resolve miss-check regression.
"""
import os
import pickle
import signal
import threading
import time

import pytest

from repro.core import (ProxyResolveError, Store, get_factory, resolve_async,
                        unregister_store)
from repro.core.connectors import (EndpointConnector, FileConnector,
                                   KVServerConnector, LocalMemoryConnector)
from repro.core.deploy import start_endpoint, start_relay
from repro.core.kv_tcp import KVClient, spawn_server, stream_item_key


# ---------------------------------------------------------------------------
# wire layer
# ---------------------------------------------------------------------------
@pytest.fixture()
def kv(tmp_path):
    host, port, pid = spawn_server(ready_file=str(tmp_path / "kv.ready"))
    client = KVClient(host, port)
    yield client
    client.shutdown_server()
    client.close()
    try:
        os.kill(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        pass


def test_wait_released_by_other_connection(kv):
    """A consumer blocked in ``wait`` is released by a producer on a
    DIFFERENT connection (the acceptance-criteria scenario)."""
    producer = KVClient(kv.host, kv.port)
    got = {}

    def consume():
        got["v"] = bytes(kv.wait("not-yet", timeout=15))

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.2)
    assert not got                      # still parked
    producer.put("not-yet", b"now-it-exists")
    t.join(10)
    assert got["v"] == b"now-it-exists"
    producer.close()


def test_wait_timeout(kv):
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        kv.wait("never-produced", timeout=0.3)
    assert time.monotonic() - t0 < 5.0


def test_wait_does_not_block_pipelined_ops(kv):
    """A parked wait completes out of order: later requests on the same
    connection overtake it (like ``sleep`` does)."""
    parked = kv.wait_async("parked-key", timeout=10)
    t0 = time.perf_counter()
    assert kv.ping()
    assert time.perf_counter() - t0 < 0.5
    assert not parked.done()
    kv.put("parked-key", b"x")
    assert bytes(parked.result(10)) == b"x"


def test_mwait_all_keys_one_exchange(kv):
    fut = kv.submit({"op": "mwait", "keys": ["ma", "mb"], "timeout": 10})
    kv.put("ma", b"A")
    kv.put("mb", b"B")
    resp = fut.result(15)
    assert [bytes(x) for x in resp["data"]] == [b"A", b"B"]


def test_mwait_timeout_lists_missing(kv):
    kv.put("present", b"p")
    with pytest.raises(TimeoutError):
        kv.mwait(["present", "absent"], timeout=0.3)


def test_stream_eos_and_refcount_interaction(kv):
    """Consumed items decref to zero and are evicted exactly once; close
    marks end-of-stream for every consumer position past the last item."""
    assert kv.stream_append("t", b"i0") == 0
    assert kv.stream_append("t", b"i1") == 1
    key0, key1 = stream_item_key("t", 0), stream_item_key("t", 1)
    assert kv.refcount(key0) == 1       # one reference: the consumer's
    it = kv.stream_next("t", 0, timeout=5)
    assert bytes(it["data"]) == b"i0" and it["available"] == 2
    assert not kv.exists(key0)          # consumed -> evicted exactly once
    assert kv.refcount(key0) == 0
    # batch prefetch path consumes too (mget2 + mdecref)
    assert [bytes(b) for b in kv.stream_fetch("t", [1])] == [b"i1"]
    assert not kv.exists(key1)
    kv.stream_close("t")
    assert kv.stream_next("t", 2, timeout=5)["end"]
    # append after close is rejected
    with pytest.raises(RuntimeError):
        kv.stream_append("t", b"late")


def test_stream_next_blocks_until_append(kv):
    res = {}

    def consume():
        res["it"] = kv.stream_next("s2", 0, timeout=10)

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.2)
    assert "it" not in res
    kv.stream_append("s2", b"first")
    t.join(10)
    assert bytes(res["it"]["data"]) == b"first"


def test_stream_item_lease_reaps_abandoned_items(kv):
    kv.stream_append("leaky", b"x", ttl=0.3)
    key = stream_item_key("leaky", 0)
    assert kv.exists(key)
    deadline = time.monotonic() + 10
    while kv.exists(key) and time.monotonic() < deadline:
        time.sleep(0.1)
    assert not kv.exists(key)           # reaped, holders presumed dead


# ---------------------------------------------------------------------------
# Store layer: ProxyFuture
# ---------------------------------------------------------------------------
@pytest.fixture()
def kv_store(kv):
    store = Store("fut-t", KVServerConnector(kv.host, kv.port))
    yield store
    store.close()


def test_proxy_future_pre_data_proxy(kv_store):
    """The future's proxy is a valid pre-data proxy: picklable and
    dispatchable before the object exists; resolve parks until
    set_result."""
    fut = kv_store.future(timeout=15)
    wire = pickle.dumps(fut.proxy())    # communicated before data exists
    results = {}

    def consume():
        results["v"] = pickle.loads(wire)["answer"]

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.2)
    assert not results
    fut.set_result({"answer": 42})
    t.join(10)
    assert results["v"] == 42
    assert fut.done()
    assert fut.result(5)["answer"] == 42


def test_proxy_future_set_exception_fans_out(kv_store):
    """set_exception propagates the producer's pickled error to EVERY
    blocked consumer (and to late resolvers)."""
    fut = kv_store.future(timeout=15)
    proxies = [fut.proxy() for _ in range(3)]
    errs = {}

    def consume(tag, p):
        try:
            _ = p + 1
        except ProxyResolveError as e:
            errs[tag] = e.__cause__

    threads = [threading.Thread(target=consume, args=(i, p))
               for i, p in enumerate(proxies[:2])]
    for t in threads:
        t.start()
    time.sleep(0.2)
    fut.set_exception(ValueError("producer exploded"))
    for t in threads:
        t.join(10)
    consume(2, proxies[2])              # late consumer: same outcome
    assert len(errs) == 3
    assert all(isinstance(e, ValueError) and "exploded" in str(e)
               for e in errs.values())


def test_proxy_future_timeout_and_double_set(kv_store):
    fut = kv_store.future(timeout=0.3)
    with pytest.raises(ProxyResolveError) as ei:
        _ = fut.proxy() + 1
    assert isinstance(ei.value.__cause__, TimeoutError)
    fut.set_result(1)
    with pytest.raises(RuntimeError):
        fut.set_result(2)               # a future is set exactly once


def test_future_fallback_connectors():
    """Local connectors get the condition-variable fallback wait."""
    store = Store("fut-mem", LocalMemoryConnector())
    try:
        fut = store.future(timeout=10)
        p = fut.proxy()
        got = {}

        def consume():
            got["v"] = p["x"]

        t = threading.Thread(target=consume)
        t.start()
        time.sleep(0.15)
        assert not got
        fut.set_result({"x": 7})
        t.join(5)
        assert got["v"] == 7
    finally:
        store.close()


# ---------------------------------------------------------------------------
# Store layer: streams
# ---------------------------------------------------------------------------
def test_stream_producer_consumer_overlap(kv_store):
    """Consumer iterates items in order while the producer is still
    appending; close yields StopIteration; stream items are consumed
    exactly once (no objects leaked on the server)."""
    before = kv_store.stats()["connector"]["n_objects"]

    def produce():
        with kv_store.stream_producer("updates", ttl=30) as prod:
            for i in range(9):
                prod.append({"i": i})
                time.sleep(0.01)

    t = threading.Thread(target=produce)
    t.start()
    seen = [obj["i"] for obj in
            kv_store.stream_consumer("updates", timeout=10, prefetch=3)]
    t.join(10)
    assert seen == list(range(9))
    assert kv_store.stats()["connector"]["n_objects"] == before


def test_stream_producer_exception_in_order(kv_store):
    with kv_store.stream_producer("failing") as prod:
        prod.append("ok-item")
        prod.append_exception(RuntimeError("worker died"))
    stream = kv_store.stream_consumer("failing", timeout=10)
    assert next(stream) == "ok-item"
    with pytest.raises(RuntimeError, match="worker died"):
        next(stream)
    with pytest.raises(StopIteration):
        next(stream)


def test_stream_fallback_memory_connector():
    store = Store("stream-mem", LocalMemoryConnector())
    try:
        with store.stream_producer("s") as prod:
            for i in range(5):
                prod.append(i * 10)
        assert list(store.stream_consumer("s", timeout=5)) == \
            [0, 10, 20, 30, 40]
    finally:
        store.close()


def test_socket_stream_across_nodes(tmp_path):
    """A consumer on node B reads node A's topic via location (the topic
    lives on the producing node's server)."""
    from repro.core.connectors import SocketConnector

    ca = SocketConnector(str(tmp_path / "disc"), node_id="nodeA")
    cb = SocketConnector(str(tmp_path / "disc"), node_id="nodeB")
    try:
        ca.stream_append("xnode", b"from-A")
        ca.stream_close("xnode")
        it = cb.stream_next("xnode", 0, timeout=5, location="nodeA")
        assert bytes(it.data) == b"from-A"
        assert cb.stream_next("xnode", 1, timeout=5, location="nodeA").end
    finally:
        for c in (ca, cb):
            c.shutdown_server()
            c.close()


def test_fl_pipeline_rejects_in_process_stream_connector(tmp_path):
    """pipeline=True must fail loudly on a connector whose streams are
    process-local (FaaS workers are separate processes)."""
    from repro.configs import ARCHS
    from repro.federated.faas import CloudModel, FaasExecutor
    from repro.federated.fl import FLConfig, FLOrchestrator

    tiny = ARCHS["phi4-mini-3.8b"].reduced().replace(
        n_layers=1, d_model=32, d_ff=64, vocab=64, dtype="float32")
    store = Store("fl-bad-pipe", FileConnector(str(tmp_path / "fl")))
    ex = FaasExecutor(n_workers=1, cloud=CloudModel(latency_s=0.0))
    try:
        orch = FLOrchestrator(
            tiny, FLConfig(rounds=1, workers_per_round=1,
                           transport="proxy", pipeline=True), ex, store)
        with pytest.raises(ValueError, match="server-backed"):
            orch.run()
    finally:
        ex.shutdown()
        store.close()


# ---------------------------------------------------------------------------
# PS-endpoint: peer-forwarded wait + located streams
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fabric(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("fut-fabric"))
    relay = start_relay(d)
    ep_a = start_endpoint(d, relay.address, name="a")
    ep_b = start_endpoint(d, relay.address, name="b")
    yield relay, ep_a, ep_b
    for h in (ep_a, ep_b, relay):
        h.stop()


def test_wait_across_peer_forwarding(fabric):
    """A consumer at endpoint B blocks in ``wait`` on a key its peer (A)
    will produce; the put at A releases it over the peer channel (the
    acceptance-criteria endpoint scenario)."""
    _, ep_a, ep_b = fabric
    ca = EndpointConnector(address=ep_a.address)
    cb = EndpointConnector(address=ep_b.address)
    key = ca.reserve()
    got = {}

    def consume():
        got["v"] = bytes(cb.wait(key, timeout=20))

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.3)
    assert not got                      # parked at A, via B
    ca.put_to(key, b"produced-at-A")
    t.join(15)
    assert got.get("v") == b"produced-at-A"
    ca.close()
    cb.close()


def test_endpoint_wait_timeout_and_local(fabric):
    _, ep_a, _ = fabric
    ca = EndpointConnector(address=ep_a.address)
    with pytest.raises(TimeoutError):
        ca.wait(ca.reserve(), timeout=0.3)
    key = ca.reserve()
    ca.put_to(key, b"local")
    assert bytes(ca.wait(key, timeout=5)) == b"local"
    ca.close()


def test_endpoint_stream_across_peers(fabric):
    """Producer streams at A; a consumer at B iterates via the topic's
    location (peer-forwarded s_next + forwarded batch fetch)."""
    _, ep_a, ep_b = fabric
    sa = Store("fut-ep-a", EndpointConnector(address=ep_a.address))
    sb = Store("fut-ep-b", EndpointConnector(address=ep_b.address))
    try:
        prod = sa.stream_producer("xsite")
        loc = prod.location
        assert loc == sa.connector.endpoint_uuid

        def produce():
            for i in range(6):
                prod.append({"i": i})
                time.sleep(0.01)
            prod.close()

        t = threading.Thread(target=produce)
        t.start()
        seen = [o["i"] for o in
                sb.stream_consumer("xsite", timeout=15, location=loc)]
        t.join(10)
        assert seen == list(range(6))
    finally:
        sa.close()
        sb.close()
        unregister_store("fut-ep-a")
        unregister_store("fut-ep-b")


def test_cross_process_future_via_pickled_proxy(kv):
    """A pre-data proxy re-materializes its store from config (fresh
    registry = another 'process') and still parks/resolves."""
    store = Store("xproc-fut", KVServerConnector(kv.host, kv.port))
    fut = store.future(timeout=15)
    wire = pickle.dumps(fut.proxy())
    key = fut.key
    store.close()                       # forget the producing store
    consumer_store = Store("xproc-fut",
                           KVServerConnector(kv.host, kv.port))
    got = {}

    def consume():
        got["v"] = pickle.loads(wire)["late"]

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.2)
    from repro.core import serialize

    KVClient(kv.host, kv.port).put(key[3], serialize({"late": True}))
    t.join(10)
    assert got["v"] is True
    consumer_store.close()


# ---------------------------------------------------------------------------
# batch-resolve miss check (regression: silent default=None fill)
# ---------------------------------------------------------------------------
def test_batch_resolved_sibling_of_evicted_key_raises():
    """Batch resolution must fail loudly (LookupError) for proxies of an
    over-evicted key — same as the scalar path's peek() — while proxies of
    OTHER keys in the batch still resolve."""
    store = Store("batch-miss", LocalMemoryConnector(), cache_size=0)
    try:
        alive = store.proxy({"ok": 1})
        dead1 = store.proxy({"gone": 1})
        dead2 = store.proxy({"gone": 1})
        store.evict(get_factory(dead1).key)
        store.evict(get_factory(dead2).key)
        resolve_async([alive, dead1, dead2])
        assert alive["ok"] == 1         # sibling of another key: fine
        for p in (dead1, dead2):
            with pytest.raises(ProxyResolveError) as ei:
                _ = p["gone"]
            assert isinstance(ei.value.__cause__, LookupError)
    finally:
        store.close()


def test_get_batch_strict_raises_like_scalar():
    store = Store("strict-batch", LocalMemoryConnector(), cache_size=0)
    try:
        k1 = store.put({"a": 1})
        k2 = store.put({"b": 2})
        store.evict(k2)
        # non-strict keeps the documented default-fill contract
        assert store.get_batch([k1, k2]) == [{"a": 1}, None]
        with pytest.raises(LookupError):
            store.get_batch([k1, k2], strict=True)
    finally:
        store.close()


def test_resolve_async_batch_with_pre_data_future(kv_store):
    """A pre-data future proxy in a resolve_async batch must PARK in wait
    (not be mistaken for an evicted key by the group miss check)."""
    fut = kv_store.future(timeout=15)
    pre = fut.proxy()
    plain = kv_store.proxy({"x": 1})
    resolve_async([pre, plain])
    assert plain["x"] == 1
    time.sleep(0.2)
    fut.set_result({"y": 2})
    assert pre["y"] == 2


def test_failed_future_key_raises_through_every_read_path(kv_store):
    """set_exception's stored error re-raises via get, get_batch, and a
    plain (non-wait) proxy of the key — not just via wait_get."""
    fut = kv_store.future(timeout=10)
    fut.set_exception(ValueError("boom"))
    with pytest.raises(ValueError, match="boom"):
        kv_store.get(fut.key)
    with pytest.raises(ValueError, match="boom"):
        kv_store.get_batch([fut.key])
    with pytest.raises(ProxyResolveError) as ei:
        _ = kv_store.proxy_from_key(fut.key)["x"]
    assert isinstance(ei.value.__cause__, ValueError)


def test_get_batch_stored_none_is_not_a_miss():
    """A legitimately-stored None must survive strict mode (the _MISS
    sentinel keeps it distinct from an evicted key)."""
    store = Store("none-batch", LocalMemoryConnector(), cache_size=0)
    try:
        k = store.put(None)
        assert store.get_batch([k], strict=True) == [None]
    finally:
        store.close()
