"""Proxy transparency / laziness / pickling (paper §3.3 contract)."""
import pickle

import numpy as np
import pytest

try:  # optional: property tests only run when hypothesis is installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (Proxy, ProxyResolveError, extract, get_factory,
                        is_proxy, is_resolved, resolve)


def test_laziness_and_single_resolution():
    calls = []

    def factory():
        calls.append(1)
        return [1, 2, 3]

    p = Proxy(factory)
    assert not is_resolved(p)
    assert len(calls) == 0
    assert p[0] == 1
    assert is_resolved(p)
    assert len(p) == 3
    assert len(calls) == 1  # factory called exactly once


def test_transparency_isinstance_and_class():
    p = Proxy(lambda: {"a": 1})
    assert isinstance(p, dict)
    assert p.__class__ is dict
    assert type(p) is Proxy  # type() still sees the proxy (documented)


def test_operator_forwarding():
    p = Proxy(lambda: 10)
    assert p + 5 == 15
    assert 5 + p == 15          # reflected
    assert p * 2 == 20
    assert p - 1 == 9
    assert 100 - p == 90
    assert p / 4 == 2.5
    assert p // 3 == 3
    assert p % 3 == 1
    assert -p == -10
    assert abs(Proxy(lambda: -3)) == 3
    assert p > 5 and p < 11 and p == 10 and p != 9
    assert divmod(p, 3) == (3, 1)
    assert int(p) == 10 and float(p) == 10.0
    assert list(range(3))[Proxy(lambda: 1)] == 1  # __index__


def test_container_and_call_forwarding():
    p = Proxy(lambda: {"x": 1})
    p["y"] = 2
    assert "y" in p and p["y"] == 2
    del p["y"]
    assert "y" not in p
    assert sorted(iter(p)) == ["x"]
    pf = Proxy(lambda: lambda a: a * 2)
    assert pf(21) == 42


def test_numpy_interop():
    arr = np.arange(6.0)
    p = Proxy(lambda: arr)
    np.testing.assert_array_equal(np.asarray(p), arr)
    np.testing.assert_array_equal(p + 1, arr + 1)
    np.testing.assert_array_equal(2 * p, 2 * arr)
    assert (p @ arr) == float(arr @ arr)
    assert p.shape == (6,)
    assert p.sum() == 15.0


def test_pickle_carries_factory_not_target():
    big = np.zeros(100_000, np.float32)

    def factory():
        return big

    # module-level functions pickle by reference; lambdas don't — use a
    # partial over an importable function for the size assertion
    from functools import partial

    p = Proxy(partial(np.zeros, 100_000, np.float32))
    blob = pickle.dumps(p)
    assert len(blob) < 500
    p2 = pickle.loads(blob)
    assert not is_resolved(p2)
    assert p2.shape == (100_000,)


def test_attribute_set_delete():
    class Obj:
        pass

    target = Obj()
    p = Proxy(lambda: target)
    p.foo = 42
    assert target.foo == 42 and p.foo == 42
    del p.foo
    assert not hasattr(target, "foo")


def test_factory_error_wrapped():
    def bad():
        raise ValueError("boom")

    p = Proxy(bad)
    with pytest.raises(ProxyResolveError, match="boom"):
        _ = len(p)


def test_extract_resolve_helpers():
    p = Proxy(lambda: "hello")
    resolve(p)
    assert is_resolved(p)
    assert extract(p) == "hello"
    assert callable(get_factory(p))
    assert is_proxy(p) and not is_proxy("hello")


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.one_of(
        st.integers(min_value=-10**6, max_value=10**6),
        st.floats(allow_nan=False, allow_infinity=False, width=32),
        st.text(max_size=40),
        st.lists(st.integers(), max_size=10),
        st.dictionaries(st.text(max_size=5), st.integers(), max_size=5),
    ))
    def test_property_proxy_equals_target(value):
        p = Proxy(lambda: value)
        assert p == value
        assert isinstance(p, type(value))
        if hasattr(value, "__len__"):
            assert len(p) == len(value)
        assert repr(p) == repr(value)
        assert str(p) == str(value)
