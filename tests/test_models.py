"""Per-arch smoke tests (deliverable f) + cross-family consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.models.model import build_model


def tiny_batch(cfg, B=2, S=32, with_labels=True, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["vision_emb"] = jax.random.normal(
            ks[2], (B, cfg.n_img_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.02
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_frames, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.02
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_reduced_train_step(arch):
    """Reduced same-family config: one forward/train step, shapes + finite."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = tiny_batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all(), arch
    # shapes preserved through the update path
    assert jax.tree.structure(grads) == jax.tree.structure(params)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = tiny_batch(cfg, B=B, S=S, with_labels=False)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(S - 1 if cfg.family not in ("ssm",) else S, jnp.int32)
    # write into the last slot for attention caches (capacity == S)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()


def test_decode_matches_full_forward_dense():
    cfg = ARCHS["qwen2.5-14b"].reduced().replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    S = 32
    toks = jax.random.randint(jax.random.key(2), (1, S + 1), 0, cfg.vocab)
    full, _ = model.prefill(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    cache = {k: jnp.concatenate(
        [v, jnp.zeros((*v.shape[:2], 1, *v.shape[3:]), v.dtype)], axis=2)
        for k, v in cache.items()}
    dec, _ = model.decode_step(params, cache, toks[:, S:],
                               jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache():
    cfg = ARCHS["mixtral-8x7b"].reduced().replace(
        dtype="float32", capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    S = cfg.sliding_window  # ring exactly full
    toks = jax.random.randint(jax.random.key(2), (1, S + 1), 0, cfg.vocab)
    full, _ = model.prefill(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    dec, _ = model.decode_step(params, cache, toks[:, S:],
                               jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_scatter_equals_einsum():
    cfg_e = ARCHS["qwen3-moe-30b-a3b"].reduced().replace(
        dtype="float32", moe_impl="einsum")
    cfg_s = cfg_e.replace(moe_impl="scatter")
    me, ms = build_model(cfg_e), build_model(cfg_s)
    params = me.init(jax.random.key(0))
    batch = tiny_batch(cfg_e)
    (l1, _), g1 = jax.value_and_grad(lambda p: me.loss(p, batch),
                                     has_aux=True)(params)
    (l2, _), g2 = jax.value_and_grad(lambda p: ms.loss(p, batch),
                                     has_aux=True)(params)
    assert abs(float(l1) - float(l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (property of the
    chunked decomposition)."""
    cfg = ARCHS["mamba2-2.7b"].reduced().replace(dtype="float32")
    model8 = build_model(cfg.replace(ssm_chunk=8))
    model32 = build_model(cfg.replace(ssm_chunk=32))
    params = model8.init(jax.random.key(0))
    batch = tiny_batch(cfg)
    l1, _ = model8.loss(params, batch)
    l2, _ = model32.loss(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_scan_vs_unrolled_equivalence():
    for arch in ("qwen2.5-14b", "mamba2-2.7b"):
        cfg = ARCHS[arch].reduced().replace(dtype="float32")
        m_scan = build_model(cfg)
        m_loop = build_model(cfg.replace(scan_layers=False))
        params = m_scan.init(jax.random.key(0))
        batch = tiny_batch(cfg)
        l1, _ = m_scan.loss(params, batch)
        l2, _ = m_loop.loss(params, batch)
        assert abs(float(l1) - float(l2)) < 1e-5


def test_vlm_loss_masks_image_positions():
    cfg = ARCHS["internvl2-26b"].reduced().replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = tiny_batch(cfg)
    # corrupting labels at image positions must not change the loss
    l1, _ = model.loss(params, batch)
    labels2 = batch["labels"].at[:, :cfg.n_img_tokens].set(0)
    l2, _ = model.loss(params, dict(batch, labels=labels2))
    assert abs(float(l1) - float(l2)) < 1e-6


def test_param_counts_match_published():
    expected = {"qwen2.5-14b": 14.8, "llama3-405b": 405.9,
                "qwen3-moe-30b-a3b": 30.5, "mixtral-8x7b": 46.7,
                "mamba2-2.7b": 2.8, "zamba2-1.2b": 1.2}
    for name, billions in expected.items():
        tot, _ = get_arch(name).param_count()
        assert abs(tot / 1e9 - billions) / billions < 0.06, name


def test_shape_applicability_matrix():
    runnable = sum(
        shape_applicable(a, s)[0]
        for a in ARCHS.values() for s in SHAPES.values())
    assert runnable == 33  # 40 cells - 7 long_500k full-attention skips
