"""Sharded KV fabric: ring routing, replication, failover, rebalancing,
and the chaos fault-injection harness.

The ``chaos``-marked tests SIGKILL shards / corrupt frames mid-workload —
they run in the nightly tier alongside ``slow``.
"""
from __future__ import annotations

import collections
import json
import time

import pytest

from repro.core.deploy import start_kvserver
from repro.core.fabric import HashRing, ShardedConnector, ShardHealth
from repro.core.kv_tcp import IDEMPOTENT_OPS, KVClient
from repro.core.multi import MultiConnector, Policy
from repro.core.store import Store, StoreConfig
from repro.distributed.chaos import ChaosProxy, kill_shard
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               HeartbeatWriter)


@pytest.fixture
def cluster(tmp_path):
    """Four UDS shards + a replication-2 quorum connector over them."""
    handles = [start_kvserver(str(tmp_path), name=f"s{i}", uds=True)
               for i in range(4)]
    fab = ShardedConnector([h.host for h in handles], replication=2,
                           quorum=True, op_timeout=5.0)
    yield handles, fab
    fab.close()
    for h in handles:
        h.stop()


# ---------------------------------------------------------------------------
# ring + health units (no servers)
# ---------------------------------------------------------------------------
def test_ring_balance_and_adjacency():
    shards = [f"10.0.0.{i}:7000" for i in range(8)]
    ring = HashRing(shards)
    keys = [f"key-{i}" for i in range(4000)]
    counts = collections.Counter(ring.primary(k) for k in keys)
    assert set(counts) == set(shards)
    assert max(counts.values()) < 3.5 * min(counts.values())  # vnode spread
    # owners are distinct, stable, and replication-sized
    owners = ring.owners("some-key", 3)
    assert len(owners) == len(set(owners)) == 3
    assert ring.owners("some-key", 3) == owners
    # membership change only remaps ring-adjacent ranges: every key that
    # didn't map to the removed shard keeps its primary
    smaller = ring.minus(shards[3])
    moved = [k for k in keys if ring.primary(k) != smaller.primary(k)]
    assert all(ring.primary(k) == shards[3] for k in moved)
    assert smaller.version == ring.version + 1


def test_shard_health_half_open():
    h = ShardHealth(probe_base_s=0.05, probe_max_s=0.2)
    assert h.usable("a")
    h.mark_suspect("a")
    assert not h.usable("a")            # circuit open
    assert h.suspects() == ["a"]
    assert h.dead(["a", "b"]) == ["a"]  # HeartbeatMonitor shape
    time.sleep(0.06)
    assert h.usable("a")                # half-open: one probe allowed
    assert not h.usable("a")            # ...and only one until next window
    h.mark_ok("a")
    assert h.usable("a") and h.suspects() == []


def test_idempotent_classification_and_retry_counter(monkeypatch):
    client = KVClient("127.0.0.1", 1)   # never actually connects
    assert {"get", "get2", "mget2", "exists", "refcount", "touch",
            "s_stat"} <= IDEMPOTENT_OPS
    assert not {"put2", "mput2", "incref", "decref", "s_append"} \
        & IDEMPOTENT_OPS
    calls = []

    def flaky(msg, payload=None):
        calls.append(msg["op"])
        raise ConnectionError("injected")

    monkeypatch.setattr(client, "submit", flaky)
    with pytest.raises(ConnectionError):
        client.get("k")                 # idempotent: retried per policy
    assert len(calls) == client.retry_policy.max_attempts
    assert client.n_retries == client.retry_policy.max_attempts - 1
    calls.clear()
    with pytest.raises(ConnectionError):
        client.put("k", b"v")           # mutation: fail-fast
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# fabric over live shards
# ---------------------------------------------------------------------------
def test_fabric_put_get_replication(cluster):
    handles, fab = cluster
    keys = fab.put_batch([f"blob-{i}".encode() * 50 for i in range(64)])
    got = fab.get_batch(keys)
    assert [bytes(b) for b in got] == \
        [f"blob-{i}".encode() * 50 for i in range(64)]
    # every key is physically present on `replication` distinct shards
    clients = [KVClient(h.host, h.port) for h in handles]
    for key in keys[:8]:
        n = sum(c.exists(key[1]) for c in clients)
        assert n == fab.replication
    for c in clients:
        c.close()
    # single-key ops + lifecycle fan-out
    k = fab.put(b"solo")
    assert bytes(fab.get(k)) == b"solo"
    assert fab.exists(k)
    assert fab.incref(k, 2) == 2
    assert fab.refcount(k) == 2
    assert fab.touch(k, 30.0)
    assert fab.decref(k) == 1
    fab.evict(k)
    assert not fab.exists(k)


def test_fabric_pipeline_round_trip(cluster):
    handles, fab = cluster
    blobs = [f"p-{i}".encode() * 40 for i in range(32)]
    with fab.pipeline() as p:
        keys = p.put_batch(blobs)
        h = p.get_batch(keys)          # FIFO: sees the puts above
        p.evict_batch(keys)
    got = h.result()
    assert [bytes(b) for b in got] == blobs
    assert all(fab.get(k) is None for k in keys)   # evicts landed too
    # reading before flush is a usage error, loudly
    p2 = fab.pipeline()
    h2 = p2.get_batch(keys)
    with pytest.raises(RuntimeError, match="flush"):
        h2.result()
    p2.flush()
    assert h2.result() == [None] * len(keys)


@pytest.mark.chaos
def test_fabric_pipeline_get_fails_over_after_kill(cluster):
    handles, fab = cluster
    blobs = [f"q-{i}".encode() * 40 for i in range(16)]
    keys = fab.put_batch(blobs)
    # kill the shard the pipeline would prefer for some keys: the flush
    # must transparently re-fetch those through the failover read path
    kill_shard(handles[0])
    with fab.pipeline() as p:
        h = p.get_batch(keys)
    assert [bytes(b) for b in h.result()] == blobs
    assert fab.n_failovers > 0


def test_fabric_store_roundtrip_and_stats(cluster, tmp_path):
    handles, fab = cluster
    cfg = StoreConfig.fabric("fab-store", [h.host for h in handles],
                             quorum=True)
    store = cfg.build()
    try:
        p = store.proxy({"weights": list(range(100))})
        assert p["weights"][-1] == 99
        st = store.stats()
        f = st["connector"]["fabric"]
        assert f["n_shards"] == 4 and f["replication"] == 2
        assert "n_reconnects" in f and "n_retries" in f
        # config round-trips: a rebuilt connector sees the same ring
        fab2 = ShardedConnector(**fab.config())
        assert fab2.shards == fab.shards
        fab2.close()
    finally:
        store.close()


def test_fabric_futures_and_streams(cluster):
    _handles, fab = cluster
    key = fab.reserve()
    fab.put_to(key, b"later")
    assert bytes(fab.wait(key, timeout=5.0)) == b"later"
    fab.stream_append("topic-a", b"item0")
    it = fab.stream_next("topic-a", 0, timeout=5.0)
    assert bytes(it.data) == b"item0" and not it.end
    fab.stream_close("topic-a")
    assert fab.stream_next("topic-a", 1, timeout=5.0).end


def test_fabric_rebalance_join_leave(cluster, tmp_path):
    handles, fab = cluster
    keys = fab.put_batch([f"v{i}".encode() * 20 for i in range(80)])
    k = keys[0]
    fab.incref(k, 3)
    fab.touch(k, 60.0)
    # join: only adjacent ranges migrate; everything stays resolvable
    extra = start_kvserver(str(tmp_path), name="s-extra", uds=True)
    try:
        fab.add_shard(extra.host)
        assert len(fab.shards) == 5
        assert all(b is not None for b in fab.get_batch(keys))
        # graceful leave: the drained shard's keys move, refcounts and
        # leases survive on the new owners
        fab.remove_shard(handles[0].host)
        assert len(fab.shards) == 4
        assert all(b is not None for b in fab.get_batch(keys))
        assert fab.refcount(k) == 3
    finally:
        extra.stop()


# ---------------------------------------------------------------------------
# chaos tier: real faults
# ---------------------------------------------------------------------------
@pytest.mark.chaos
def test_kill_primary_mid_put_replica_serves_read(cluster):
    handles, fab = cluster
    keys = fab.put_batch([f"pre-kill-{i}".encode() * 30
                          for i in range(40)])
    victim = handles[0]
    kill_shard(victim)
    # zero committed puts lost: every pre-kill key resolves via failover
    got = fab.get_batch(keys)
    assert all(b is not None for b in got)
    assert fab.n_failovers > 0
    assert victim.host in fab.stats()["fabric"]["suspect"]
    # writes keep working with the shard down (remaining owners ack)
    k2 = fab.put(b"post-kill")
    assert bytes(fab.get(k2)) == b"post-kill"


@pytest.mark.chaos
def test_lease_and_refcount_survive_shard_death(cluster):
    handles, fab = cluster
    k = fab.put(b"owned")
    fab.incref(k, 2)
    fab.touch(k, 60.0)
    kill_shard(handles[1])
    # counts were replicated with the key: surviving owner agrees
    assert fab.refcount(k) == 2
    assert bytes(fab.get(k)) == b"owned"
    # repair: re-replicate onto the remaining shards; state intact
    fab.remove_shard(handles[1].host, dead=True)
    assert fab.refcount(k) == 2
    assert fab.touch(k, 60.0)
    assert bytes(fab.get(k)) == b"owned"


@pytest.mark.chaos
def test_rebalance_under_churn_keeps_keys_resolvable(cluster, tmp_path):
    import threading

    handles, fab = cluster
    keys = fab.put_batch([f"churn-{i}".encode() * 10 for i in range(40)])
    written: list = []
    stop = threading.Event()

    def writer() -> None:
        while not stop.is_set():
            written.append(fab.put(b"churned" * 10))
            time.sleep(0.002)

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    extra = start_kvserver(str(tmp_path), name="churn-extra", uds=True)
    try:
        fab.add_shard(extra.host)          # join under live writes
        fab.remove_shard(handles[2].host)  # ...then a graceful leave
        stop.set()
        t.join(timeout=5.0)
        assert written                     # churn actually happened
        for k in keys + written:           # every key still resolves
            assert fab.get(k) is not None, k
    finally:
        stop.set()
        extra.stop()


@pytest.mark.chaos
def test_chaosproxy_corruption_marks_stream_dead(tmp_path):
    shard = start_kvserver(str(tmp_path), name="c0", uds=True)
    proxy = ChaosProxy(shard.host, shard.port)
    client = KVClient(proxy.host, proxy.port, timeout=5.0)
    try:
        client.put("k", b"v" * 100)
        assert bytes(client.get("k")) == b"v" * 100
        # corrupt the next request's frame-length header: the server must
        # declare the stream DEAD (connection dropped), never parse it
        proxy.corrupt_next()
        with pytest.raises(ConnectionError):
            client.put("k2", b"x" * 100)   # mutation: fails fast
        # the server itself survived and the data plane is intact
        assert bytes(client.get("k")) == b"v" * 100   # reconnects
        assert client.n_reconnects >= 2
        assert not client.exists("k2")
    finally:
        client.close()
        proxy.close()
        shard.stop()


@pytest.mark.chaos
def test_chaosproxy_blackhole_and_reset(tmp_path):
    shard = start_kvserver(str(tmp_path), name="b0", uds=True)
    proxy = ChaosProxy(shard.host, shard.port)
    client = KVClient(proxy.host, proxy.port, timeout=0.5)
    try:
        client.put("k", b"v")
        proxy.blackhole(True)              # bytes vanish: pure stall
        with pytest.raises(Exception) as ei:
            client.request({"op": "get2", "key": "k"}, retry=False)
        assert "Timeout" in type(ei.value).__name__ \
            or isinstance(ei.value, ConnectionError)
        proxy.blackhole(False)
        proxy.reset_conns()                # sever: next op reconnects
        assert bytes(client.get("k")) == b"v"
        assert client.n_reconnects >= 2
    finally:
        client.close()
        proxy.close()
        shard.stop()


# ---------------------------------------------------------------------------
# satellites: multi-connector degradation + heartbeat monotonic age
# ---------------------------------------------------------------------------
class _DeadConnector:
    """Stand-in for a crashed child: every put raises ConnectionError."""

    def put(self, blob):
        raise ConnectionError("child is down")

    def put_batch(self, blobs):
        raise ConnectionError("child is down")

    def get(self, key):
        return None

    def exists(self, key):
        return False

    def evict(self, key):
        pass

    def config(self):
        return {}

    def close(self):
        pass


def test_multiconnector_put_falls_through_on_dead_child():
    from repro.core.connectors import LocalMemoryConnector

    healthy = LocalMemoryConnector()
    multi = MultiConnector([(_DeadConnector(), Policy(priority=10)),
                            (healthy, Policy(priority=0))])
    key = multi.put(b"degraded")           # high-priority child is dead
    assert key[1] == 1                     # ...landed on the fallback
    assert bytes(multi.get(key)) == b"degraded"
    keys = multi.put_batch([b"a", b"b"])
    assert all(k[1] == 1 for k in keys)
    assert [bytes(b) for b in multi.get_batch(keys)] == [b"a", b"b"]
    # every matching child dead -> the ConnectionError surfaces
    only_dead = MultiConnector([(_DeadConnector(), Policy())])
    with pytest.raises(ConnectionError):
        only_dead.put(b"x")


def test_heartbeat_age_is_monotonic_not_wallclock(tmp_path, monkeypatch):
    w = HeartbeatWriter(str(tmp_path), "w0")
    w.beat(round=1)
    mon = HeartbeatMonitor(str(tmp_path), stale_s=5.0)
    assert "w0" in mon.alive()
    # a wall-clock step of +1h must NOT declare the worker dead: age is
    # tracked on the reader's monotonic clock after first sight
    real_time = time.time
    monkeypatch.setattr(time, "time", lambda: real_time() + 3600.0)
    assert "w0" in mon.alive()
    monkeypatch.undo()
    # ...and a beat observed (seq change) resets the age
    w.beat(round=2)
    assert mon.alive()["w0"]["seq"] == 2
