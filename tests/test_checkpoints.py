"""Proxy-checkpoint manager: manifests of proxies, lazy restore, GC."""
import numpy as np
import pytest

from repro.core import Store, serialize
from repro.core.connectors import FileConnector
from repro.core.proxy import is_proxy, is_resolved
from repro.train.checkpoints import ProxyCheckpointManager


@pytest.fixture
def mgr(tmp_path):
    store = Store("ckpt-tests", FileConnector(str(tmp_path / "data")))
    return ProxyCheckpointManager(store, str(tmp_path / "ckpts"),
                                  keep_last=2, chunk_bytes=4096)


STATE = {"params": {"w": np.random.default_rng(0)
                    .standard_normal((64, 32)).astype(np.float32),
                    "b": np.zeros(32, np.float32)},
         "opt": {"step": np.int32(5)}}


def test_save_restore_roundtrip(mgr):
    mgr.save(10, STATE)
    out = mgr.restore()
    np.testing.assert_array_equal(out["params"]["w"], STATE["params"]["w"])
    assert int(out["opt"]["step"]) == 5


def test_manifest_is_tiny(mgr, tmp_path):
    mgr.save(1, STATE)
    manifest = (mgr.dir / "ckpt_00000001.manifest").read_bytes()
    assert len(manifest) < 5000          # proxies, not data
    assert len(manifest) < STATE["params"]["w"].nbytes


def test_chunked_leaves(mgr):
    """Leaves above chunk_bytes become lists of chunk proxies
    (the paper's nested-proxy partial-resolution pattern)."""
    mgr.save(2, STATE)
    man = mgr._manifest(2)
    kinds = {e["kind"] for e in man["entries"]}
    assert "chunked" in kinds            # w is 8 KB > 4 KB chunks
    assert "whole" in kinds


def test_lazy_restore_leaf_filter(mgr):
    mgr.save(3, STATE)
    out = mgr.restore(leaf_filter=lambda i: i == 0)
    leaves = [out["params"]["b"], out["params"]["w"], out["opt"]["step"]]
    resolved = [not (is_proxy(l) or (isinstance(l, list)
                                     and is_proxy(l[0]))) for l in leaves]
    assert resolved.count(True) == 1     # only the filtered leaf materialized


def test_gc_keep_last_evicts_store(mgr):
    for step in (10, 20, 30, 40):
        mgr.save(step, STATE)
    assert mgr.steps() == [30, 40]
    # the evicted manifests' objects are gone from the connector
    files = list((mgr.store.connector._dir).glob("*.obj"))
    man = mgr._manifest(40)
    n_per_ckpt = sum(1 if e["kind"] == "whole" else len(e["proxies"])
                     for e in man["entries"])
    assert len(files) <= 2 * n_per_ckpt


def test_async_save_and_wait(mgr):
    mgr.save_async(7, STATE)
    mgr.wait()
    assert mgr.latest_step() == 7
    out = mgr.restore(7)
    np.testing.assert_array_equal(out["params"]["w"], STATE["params"]["w"])


def test_restore_like_casts(mgr):
    import jax
    import jax.numpy as jnp

    state = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    mgr.save(1, state)
    like = jax.eval_shape(lambda: {"w": jnp.zeros((4, 4), jnp.bfloat16)})
    out = mgr.restore(like=like)
    assert str(np.asarray(out["w"]).dtype) == "bfloat16"


def test_missing_checkpoint_raises(mgr):
    with pytest.raises(FileNotFoundError):
        mgr.restore()
